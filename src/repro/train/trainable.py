"""ModelTrainable — the bridge between the model zoo and the Tune core.

One Tune *trial* = one ModelTrainable: a jit-compiled train step over a model
config with trial hyperparameters (lr, warmup, weight decay, optimizer choice,
microbatch, ...) pulled from ``config``.  Implements the full narrow-waist
contract: step / save / restore / reset_config — so every scheduler
(HyperBand pause/resume, PBT clone+mutate) works on real model training.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.api import Trainable
from ..data.pipeline import DataConfig, SyntheticLMDataset
from ..models import ModelConfig, param_count
from .optimizer import adamw, linear_warmup_cosine, sgd
from .train_step import TrainState, make_train_state, make_train_step

__all__ = ["ModelTrainable", "make_model_trainable", "model_trainable_factory"]


def _build_optimizer(hp: Dict[str, Any], total_steps: int):
    name = hp.get("optimizer", "adamw")
    lr = float(hp.get("lr", 3e-4))
    schedule = linear_warmup_cosine(lr, int(hp.get("warmup", 10)), total_steps)
    if name == "adamw":
        return adamw(schedule,
                     b1=float(hp.get("b1", 0.9)),
                     b2=float(hp.get("b2", 0.95)),
                     weight_decay=float(hp.get("weight_decay", 0.1)),
                     grad_clip=hp.get("grad_clip", 1.0))
    if name == "sgd":
        return sgd(schedule, momentum=float(hp.get("momentum", 0.9)),
                   weight_decay=float(hp.get("weight_decay", 0.0)),
                   grad_clip=hp.get("grad_clip", None))
    raise ValueError(f"unknown optimizer {name!r}")


class ModelTrainable(Trainable):
    """config keys: model_cfg (ModelConfig), lr/warmup/optimizer/... (hypers),
    batch/seq_len/steps_per_iter/total_steps/data_seed (workload).

    Hardware profile (DESIGN.md §9): after every (re)build the first reported
    result carries a one-shot ``_profile`` entry in its metrics — step-time
    decomposition (first step = compile + execute vs steady state), device
    memory, and with ``profile_roofline=True`` an achieved-vs-predicted
    roofline tag from ``launch/roofline.py``.  The runner pops it off the
    metric stream and publishes it as trial metadata (``trial.profile``) plus
    a PROFILE event, so it rides the existing result transport across all
    executor tiers.  Disable with ``profile=False``."""

    def setup(self, config: Dict[str, Any]) -> None:
        self.model_cfg: ModelConfig = config["model_cfg"]
        self.batch = int(config.get("batch", 8))
        self.seq_len = int(config.get("seq_len", 128))
        self.steps_per_iter = int(config.get("steps_per_iter", 5))
        self.total_steps = int(config.get("total_steps", 1000))
        self._data = SyntheticLMDataset(DataConfig(
            global_batch=self.batch, seq_len=self.seq_len,
            vocab_size=self.model_cfg.vocab_size,
            seed=int(config.get("data_seed", 0))))
        self._global_step = 0
        self._build(config)

    def _build(self, hp: Dict[str, Any]) -> None:
        self._opt = _build_optimizer(hp, self.total_steps)
        raw_step = make_train_step(self.model_cfg, self._opt,
                                   microbatch=int(hp.get("microbatch", 0)))
        seed = int(hp.get("init_seed", 0))
        self.state = make_train_state(jax.random.key(seed), self.model_cfg, self._opt)
        self._pending_profile = bool(hp.get("profile", True))
        self._compiled = None
        self._compile_s: Optional[float] = None
        if hp.get("profile_roofline"):
            # AOT compile: one explicit lower+compile that doubles as the
            # step function (the jit cache never compiles a second time) and
            # hands the roofline walk the post-fusion HLO it needs — a
            # traced-only jit exposes StableHLO, which the cost regexes
            # cannot parse.
            batch = {k: jnp.asarray(v)
                     for k, v in self._data.batch_at(self._global_step).items()}
            p0 = time.perf_counter()
            self._compiled = jax.jit(raw_step).lower(self.state, batch).compile()
            self._compile_s = time.perf_counter() - p0
            self._step_fn = self._compiled
        else:
            self._step_fn = jax.jit(raw_step)

    # -- narrow-waist contract ---------------------------------------------------
    def step(self) -> Dict[str, Any]:
        t0 = time.time()
        step_times = [] if self._pending_profile else None
        for _ in range(self.steps_per_iter):
            batch = {k: jnp.asarray(v)
                     for k, v in self._data.batch_at(self._global_step).items()}
            if step_times is None:
                self.state, metrics = self._step_fn(self.state, batch)
            else:
                # Profiled iteration only: synchronous per-step timing so the
                # first-step (compile) vs steady-state split is real, not a
                # dispatch-queue artifact.
                p0 = time.perf_counter()
                self.state, metrics = self._step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                step_times.append(time.perf_counter() - p0)
            self._global_step += 1
        loss = float(metrics["loss"])
        out = {
            "loss": loss,
            "accuracy": float(metrics["accuracy"]),
            "grad_norm": float(metrics["grad_norm"]),
            "step": self._global_step,
            "steps_per_s": self.steps_per_iter / max(time.time() - t0, 1e-9),
        }
        if step_times:
            self._pending_profile = False
            out["_profile"] = self._make_profile(step_times)
        return out

    def _make_profile(self, step_times) -> Dict[str, Any]:
        first = step_times[0]
        steady = min(step_times[1:]) if len(step_times) > 1 else first
        prof: Dict[str, Any] = {
            "first_step_s": round(first, 6),
            "steady_step_s": round(steady, 6),
            # AOT path: the measured explicit compile; jit path: the first
            # step carries the compile, so the split is the estimate.
            "compile_s": round(self._compile_s if self._compile_s is not None
                               else max(0.0, first - steady), 6),
            "param_count": int(param_count(self.state.params)),
            "batch": self.batch,
            "seq_len": self.seq_len,
        }
        try:
            stats = jax.local_devices()[0].memory_stats() or {}
            if "bytes_in_use" in stats:
                prof["device_bytes_in_use"] = int(stats["bytes_in_use"])
        except Exception:
            pass  # memory_stats is backend-dependent (absent on CPU)
        if self._compiled is not None:
            try:
                ma = self._compiled.memory_analysis()
                for key, attr in (("arg_bytes", "argument_size_in_bytes"),
                                  ("temp_bytes", "temp_size_in_bytes"),
                                  ("output_bytes", "output_size_in_bytes")):
                    v = getattr(ma, attr, None)
                    if v is not None:
                        prof[key] = int(v)
            except Exception:
                pass
            try:
                from ..launch.roofline import analyze
                rep = analyze(
                    arch=self.model_cfg.arch_id, shape_name="trial",
                    mesh_name="local", chips=1, compiled=self._compiled,
                    n_params_active=int(param_count(self.state.params)),
                    n_tokens=self.batch * self.seq_len, kind="train")
                prof["predicted_step_s"] = round(rep.step_time_s, 6)
                prof["dominant"] = rep.dominant
                prof["roofline_compute_s"] = round(rep.compute_s, 6)
                prof["roofline_memory_s"] = round(rep.memory_s, 6)
                prof["roofline_collective_s"] = round(rep.collective_s, 6)
                if rep.step_time_s > 0:
                    prof["achieved_vs_predicted"] = round(
                        steady / rep.step_time_s, 4)
            except Exception:
                pass  # roofline is best-effort decoration, never a crash
        return prof

    def save(self) -> Any:
        return {
            "state": jax.device_get(self.state._asdict()),
            "global_step": self._global_step,
        }

    def restore(self, snapshot: Any) -> None:
        st = snapshot["state"]
        as_jnp = jax.tree_util.tree_map(jnp.asarray, st)
        state = TrainState(**as_jnp)
        # A PBT mutation may have switched optimizer family: if the donor's
        # opt_state tree doesn't match this trainable's optimizer, re-init it
        # (params are what cloning is about; moments restart harmlessly).
        expect = jax.eval_shape(self._opt.init, state.params)
        if (jax.tree_util.tree_structure(expect)
                != jax.tree_util.tree_structure(state.opt_state)):
            state = TrainState(params=state.params,
                               opt_state=self._opt.init(state.params),
                               step=state.step)
        self.state = state
        self._global_step = int(snapshot["global_step"])

    def reset_config(self, new_config: Dict[str, Any]) -> bool:
        """PBT mutation: rebuild optimizer/step under new hypers, keep params."""
        self.config = dict(new_config)
        params = self.state.params
        step = self.state.step
        self._build(new_config)
        # keep model params; fresh optimizer state under the mutated hypers
        self.state = TrainState(params=params,
                                opt_state=self._opt.init(params), step=step)
        return True


def make_model_trainable(model_cfg: ModelConfig, **workload) -> type:
    """Bind a model config (and workload sizes) into a Trainable subclass."""
    defaults = dict(workload)

    class Bound(ModelTrainable):
        def setup(self, config: Dict[str, Any]) -> None:
            merged = {**defaults, "model_cfg": model_cfg, **config}
            super().setup(merged)

    Bound.__name__ = f"ModelTrainable[{model_cfg.arch_id}]"
    return Bound


def model_trainable_factory(model_cfg: ModelConfig, **workload):
    """Spawn-safe recipe for ``make_model_trainable`` — process workers rebuild
    the bound class in the child by re-importing this module and calling
    ``make_model_trainable(model_cfg, **workload)`` there (the class returned
    by ``make_model_trainable`` itself is function-local, so it cannot be
    pickled across a spawn boundary).  ``model_cfg`` and the workload kwargs
    ride along as pickled plain data."""
    from ..core.workers import TrainableFactory

    return TrainableFactory(
        target="repro.train.trainable:make_model_trainable",
        kwargs={"model_cfg": model_cfg, **workload},
        call=True,
    )
