"""Optimizers and LR schedules, from scratch (no optax in this environment).

Pytree-structured states; ``Optimizer`` is an (init, update) pair like optax —
``update`` returns (new_params, new_state) directly (fused apply) to avoid an
extra tree round-trip.  AdamW keeps fp32 master moments regardless of param
dtype (mixed-precision training convention).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer", "adamw", "sgd", "global_norm", "clip_by_global_norm",
    "cosine_schedule", "linear_warmup_cosine", "constant_schedule",
]

Schedule = Callable[[jax.Array], jax.Array]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1) -> Schedule:
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32) / max(total_steps, 1), 1.0)
        cos = 0.5 * (1 + jnp.cos(math.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup, 1)
        return jnp.where(s < warmup, warm, cos(jnp.maximum(s - warmup, 0)))
    return fn


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                                  tree), norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params) -> (params, state)


def adamw(
    schedule: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
    moment_dtype: Any = jnp.float32,
) -> Optimizer:
    """``moment_dtype=bf16`` halves optimizer-state memory (8-bit-Adam-style
    trade, coarser: moments round-trip through bf16 between steps)."""
    sched = constant_schedule(schedule) if isinstance(schedule, (int, float)) else schedule
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state["step"] + 1
        lr = sched(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m32 / c1
            vhat = v32 / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m32.astype(mdt), v32.astype(mdt))

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init=init, update=update)


def sgd(
    schedule: Schedule | float,
    momentum: float = 0.9,
    nesterov: bool = False,
    weight_decay: float = 0.0,
    grad_clip: Optional[float] = None,
) -> Optimizer:
    sched = constant_schedule(schedule) if isinstance(schedule, (int, float)) else schedule

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mom": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state["step"] + 1
        lr = sched(step)

        def upd(p, g, m):
            g32 = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m = momentum * m + g32
            d = g32 + momentum * m if nesterov else m
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["mom"])
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        return (treedef.unflatten([o[0] for o in out]),
                {"step": step, "mom": treedef.unflatten([o[1] for o in out])})

    return Optimizer(init=init, update=update)
