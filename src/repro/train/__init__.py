from .optimizer import (Optimizer, adamw, clip_by_global_norm, constant_schedule,
                        cosine_schedule, global_norm, linear_warmup_cosine, sgd)
from .train_step import TrainState, make_eval_step, make_train_state, make_train_step
from .serve_step import generate, make_decode_step, make_prefill_step, sample_tokens
