"""Train / eval steps: loss + grad + optimizer apply, with optional
gradient-accumulation microbatching.  Pure functions of (TrainState, batch) —
this is what a Tune Trainable jit-compiles per trial, and what the dry-run
lowers on the production mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import ModelConfig, forward_train, init_params
from .optimizer import Optimizer, global_norm

__all__ = ["TrainState", "make_train_state", "make_train_step", "make_eval_step"]


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array  # int32 scalar


def make_train_state(key, cfg: ModelConfig, opt: Optimizer) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, opt: Optimizer, microbatch: int = 0):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatch`` > 0 splits the per-call batch into that many accumulation
    slices along axis 0 (a lax.scan — keeps live activation memory at
    1/microbatch at the price of serialized compute).
    """

    def loss_fn(params, batch):
        loss, metrics = forward_train(params, batch, cfg)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        def slice_batch(i):
            return jax.tree_util.tree_map(
                lambda x: x.reshape(microbatch, x.shape[0] // microbatch, *x.shape[1:])[i],
                batch)

        def body(carry, i):
            acc_grads, acc_loss, acc_metrics = carry
            loss, metrics, grads = single(params, slice_batch(i))
            acc_grads = jax.tree_util.tree_map(jnp.add, acc_grads, grads)
            acc_loss = acc_loss + loss
            acc_metrics = jax.tree_util.tree_map(jnp.add, acc_metrics, metrics)
            return (acc_grads, acc_loss, acc_metrics), None

        zero_grads = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        loss0, metrics0, grads0 = single(params, slice_batch(0))
        (grads, loss, metrics), _ = jax.lax.scan(
            body, (grads0, loss0, metrics0), jnp.arange(1, microbatch))
        inv = 1.0 / microbatch
        scale = lambda t: jax.tree_util.tree_map(lambda x: x * inv, t)
        return scale(loss), scale(metrics), scale(grads)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]) -> Tuple[TrainState, Dict]:
        if microbatch and microbatch > 1:
            loss, metrics, grads = accumulated(state.params, batch)
        else:
            loss, metrics, grads = single(state.params, batch)
        new_params, new_opt = opt.update(grads, state.opt_state, state.params)
        metrics = dict(metrics)
        metrics["grad_norm"] = global_norm(grads)
        metrics["total_loss"] = loss
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = forward_train(params, batch, cfg)
        return metrics
    return eval_step
