"""Serving steps: batched prefill and single-token decode, plus a simple
batched greedy/temperature sampler loop for the serving example.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import ModelConfig, decode_step, prefill

__all__ = ["make_prefill_step", "make_decode_step", "sample_tokens"]


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return prefill(params, batch, cfg, max_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def step(params, caches, tokens, pos):
        return decode_step(params, caches, tokens, pos, cfg)
    return step


def sample_tokens(logits: jax.Array, key, temperature: float = 0.0) -> jax.Array:
    """Greedy (temperature 0) or categorical sampling. logits (B, V) -> (B,)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def generate(
    params, cfg: ModelConfig, prompt_tokens: jax.Array, n_new: int,
    temperature: float = 0.0, seed: int = 0, max_len: Optional[int] = None,
) -> jax.Array:
    """End-to-end batched generation (prefill + decode loop). Returns (B, n_new)."""
    B, S = prompt_tokens.shape
    max_len = max_len or (S + n_new)
    logits, caches = prefill(params, {"tokens": prompt_tokens}, cfg, max_len)
    key = jax.random.key(seed)
    tok = sample_tokens(logits, key, temperature)

    decode = jax.jit(lambda c, t, p: decode_step(params, c, t, p, cfg))
    out = [tok]
    for i in range(n_new - 1):
        key = jax.random.fold_in(key, i)
        logits, caches = decode(caches, tok, jnp.asarray(S + i, jnp.int32))
        tok = sample_tokens(logits, key, temperature)
        out.append(tok)
    return jnp.stack(out, axis=1)
