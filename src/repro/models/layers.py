"""Core layers: norms, RoPE, GQA/MQA attention (naive + chunked online-softmax),
gated MLPs, embeddings.  Pure JAX; the Pallas flash kernel plugs in via
``attn_impl='pallas'`` (kernels/ops.py).

Parameter containers are plain nested dicts so they stack cleanly for
scan-over-layers and shard via path-based rules (dist/sharding.py).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Params = Dict[str, Any]


# -- init helpers ---------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_norm(d: int, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig, eps: float = 1e-6) -> jax.Array:
    # Reductions in fp32 (numerics), multiplies in the activation dtype: a full
    # fp32 copy of x would otherwise be saved as a backward residual — at
    # (L, B, S, D) stacked over a scanned layer stack that doubles activation
    # memory (observed: +160 GiB/device on qwen-110b train_4k).
    if cfg.norm == "layernorm":
        mu = x.astype(jnp.float32).mean(-1, keepdims=True)
        var = jnp.square(x.astype(jnp.float32) - mu).mean(-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps)
        y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
        return y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    var = jnp.square(x.astype(jnp.float32)).mean(-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return x * inv.astype(x.dtype) * p["scale"].astype(x.dtype)


# -- rotary position embeddings ----------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin of shape (..., head_dim//2), fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (..., S, half) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # add head axis
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1).astype(x.dtype)


# -- attention ---------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (D, H * hd), dtype),
        "wk": _dense_init(ks[1], (D, K * hd), dtype),
        "wv": _dense_init(ks[2], (D, K * hd), dtype),
        "wo": _dense_init(ks[3], (H * hd, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def _qkv(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return (q.reshape(B, S, H, hd), k.reshape(B, S, K, hd), v.reshape(B, S, K, hd))


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int]) -> jax.Array:
    """(…, Sq, Sk) additive bias in fp32: 0 allowed / -inf masked."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = k_pos[..., None, :] >= 0  # ring-cache slots still empty carry kpos=-1
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _sdpa(q, k, v, bias, softcap: Optional[float]) -> jax.Array:
    """q (B,Sq,H,hd) k/v (B,Sk,K,hd) bias (B?,Sq,Sk) -> (B,Sq,H,hd). GQA via reshape."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = logits + bias[:, None, None, :, :]
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_chunked(q, k, v, q_pos, k_pos, causal, window, softcap, chunk: int) -> jax.Array:
    """Online-softmax over q-chunks: memory O(Sq_blk * Sk), never (Sq, Sk) full.

    The flash-attention recurrence over query blocks (k/v stay resident); used
    for long-sequence shapes where the naive (Sq, Sk) score tensor would not
    fit HBM.  fp32 accumulators.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    n_chunks = -(-Sq // chunk)
    pad = n_chunks * chunk - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    qc = q.reshape(B, n_chunks, chunk, H, hd)
    pc = q_pos.reshape(B, n_chunks, chunk)

    # jax.checkpoint: without it, autodiff saves every chunk's (chunk, Sk)
    # logits across the scan — exactly the O(Sq*Sk) blow-up this code exists
    # to avoid.  Rematerializing the chunk in backward keeps memory O(chunk*Sk).
    @jax.checkpoint
    def body(_, xs):
        qb, pb = xs  # (B, chunk, H, hd), (B, chunk)
        qg = qb.reshape(B, chunk, K, G, hd)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        bias = _mask_bias(pb, k_pos, causal, window)  # (B, chunk, Sk)
        logits = logits + bias[:, None, None, :, :]
        m = jnp.max(logits, axis=-1, keepdims=True)
        m = jnp.maximum(m, -1e30)  # rows fully masked
        p = jnp.exp(logits - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgqs,bskd->bqkgd", (p / jnp.maximum(l, 1e-30)).astype(q.dtype), v)
        return None, o.reshape(B, chunk, H, hd)

    _, out = jax.lax.scan(body, None, (qc.swapaxes(0, 1), pc.swapaxes(0, 1)))
    out = out.swapaxes(0, 1).reshape(B, n_chunks * chunk, H, hd)
    return out[:, :Sq]


def project_qkv(p: Params, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """QKV projection + RoPE.  Returns q (B,S,H,hd), k/v (B,S,K,hd)."""
    q, k, v = _qkv(p, x, cfg)
    cos, sin = rope_angles(positions, cfg.hd, cfg.rope_theta)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def attend(q: jax.Array, k_all: jax.Array, v_all: jax.Array,
           q_pos: jax.Array, k_pos: jax.Array, cfg: ModelConfig,
           causal: bool = True, window: Optional[int] = None,
           impl: Optional[str] = None) -> jax.Array:
    """Scaled-dot-product attention core with mask from positions."""
    impl = impl or cfg.attn_impl
    S = q.shape[1]
    if impl == "auto":
        impl = "chunked" if (k_all.shape[1] > 2048 and S > 1) else "naive"
    if impl == "pallas" and S > 1:
        from ..kernels import ops as kops
        return kops.flash_attention(q, k_all, v_all, q_pos, k_pos,
                                    causal=causal, window=window,
                                    softcap=cfg.logit_softcap)
    if impl == "chunked" and S > 1:
        return _sdpa_chunked(q, k_all, v_all, q_pos, k_pos, causal, window,
                             cfg.logit_softcap, cfg.attn_chunk)
    bias = _mask_bias(q_pos, k_pos, causal, window)
    return _sdpa(q, k_all, v_all, bias, cfg.logit_softcap)


def attention(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Self-attention layer (no cache).  Returns (out, (k, v)) — this call's
    post-RoPE keys/values so callers can fill decode caches (prefill)."""
    q, k_new, v_new = project_qkv(p, x, cfg, positions)
    out = attend(q, k_new, v_new, positions, positions, cfg, causal, window, impl)
    B, S_, H, hd = out.shape
    y = out.reshape(B, S_, H * hd) @ p["wo"]
    return y, (k_new, v_new)


# -- MLPs -------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(ks[0], (D, F), dtype),
            "w_up": _dense_init(ks[1], (D, F), dtype),
            "w_down": _dense_init(ks[2], (F, D), dtype),
        }
    return {
        "w_in": _dense_init(ks[0], (D, F), dtype),
        "b_in": jnp.zeros((F,), dtype),
        "w_out": _dense_init(ks[1], (F, D), dtype),
        "b_out": jnp.zeros((D,), dtype),
    }


def apply_mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.activation == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if cfg.activation == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_in"] + p["b_in"].astype(x.dtype), approximate=True)
    return h @ p["w_out"] + p["b_out"].astype(x.dtype)


# -- embeddings ----------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    V = cfg.padded_vocab or cfg.vocab_size
    p = {"tok": (jax.random.normal(key, (V, cfg.d_model)) * 0.02).astype(dtype)}
    return p


def embed_tokens(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(jnp.dtype(cfg.activation_dtype))
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def lm_logits(embed_p: Params, head_p: Optional[Params], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings or head_p is None:
        logits = x @ embed_p["tok"].T.astype(x.dtype)
    else:
        logits = x @ head_p["w"].astype(x.dtype)
    if cfg.padded_vocab and cfg.padded_vocab > cfg.vocab_size:
        # mask padding rows: -inf contributes nothing to logsumexp/argmax and
        # keeps the padded (shardable) vocab axis intact — no unsharded slice
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab_size,
                           logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def init_lm_head(key, cfg: ModelConfig) -> Optional[Params]:
    if cfg.tie_embeddings:
        return None
    dtype = jnp.dtype(cfg.param_dtype)
    V = cfg.padded_vocab or cfg.vocab_size
    return {"w": _dense_init(key, (cfg.d_model, V), dtype, scale=0.02)}
