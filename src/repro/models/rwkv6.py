"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free, data-dependent decay.

Time-mixing:   S_t = diag(w_t) S_{t-1} + k_t v_t^T,
               y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
with per-channel data-dependent decay w_t = exp(-exp(w0 + lora_w(x))) and
data-dependent token-shift interpolation (DDLerp) for r/k/v/w/g.

Training uses an exact *chunked* evaluation: within a chunk, intra-chunk
contributions are a masked matmul with decay-ratio weights computed in log
space (ratios are always <= 1, so no overflow); inter-chunk state is carried by
``lax.scan``.  Decode is the plain single-step recurrence.  The Pallas kernel
(kernels/rwkv6_scan.py) implements the same chunked scheme with VMEM tiling.

Channel-mixing: squared-ReLU MLP with static token-shift (Finch eq. 20-22).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense_init

Params = Dict[str, Any]

_N_MIX = 5  # r, k, v, w, g
_LORA_MIX = 32
_LORA_DECAY = 64


def init_time_mix(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    N = cfg.rwkv_head_dim
    H = D // N
    ks = jax.random.split(key, 10)
    return {
        "mu_x": jnp.zeros((D,), dtype),
        "mu_rkvwg": jnp.zeros((_N_MIX, D), dtype),
        "maa_w1": _dense_init(ks[0], (D, _N_MIX * _LORA_MIX), dtype, scale=1e-2),
        "maa_w2": _dense_init(ks[1], (_N_MIX, _LORA_MIX, D), dtype, scale=1e-2),
        "w0": jnp.full((D,), -6.0, dtype),  # slow initial decay
        "w_lora_a": _dense_init(ks[2], (D, _LORA_DECAY), dtype, scale=1e-2),
        "w_lora_b": _dense_init(ks[3], (_LORA_DECAY, D), dtype, scale=1e-2),
        "u": (jax.random.normal(ks[4], (H, N)) * 0.1).astype(dtype),
        "w_r": _dense_init(ks[5], (D, D), dtype),
        "w_k": _dense_init(ks[6], (D, D), dtype),
        "w_v": _dense_init(ks[7], (D, D), dtype),
        "w_g": _dense_init(ks[8], (D, D), dtype),
        "w_o": _dense_init(ks[9], (D, D), dtype),
        "ln_x_scale": jnp.ones((D,), dtype),
        "ln_x_bias": jnp.zeros((D,), dtype),
    }


def init_channel_mix(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((D,), dtype),
        "mu_r": jnp.zeros((D,), dtype),
        "w_k": _dense_init(ks[0], (D, F), dtype),
        "w_v": _dense_init(ks[1], (F, D), dtype),
        "w_r": _dense_init(ks[2], (D, D), dtype),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """s_t = x_{t-1}; position 0 uses ``prev`` (decode state) or zeros."""
    if x.shape[1] == 1:
        return prev[:, None, :] if prev is not None else jnp.zeros_like(x)
    first = prev[:, None, :] if prev is not None else jnp.zeros_like(x[:, :1])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(p: Params, x: jax.Array, s: jax.Array) -> jax.Array:
    """Data-dependent lerp -> (5, B, S, D) mixed inputs for r/k/v/w/g."""
    xm = x + (s - x) * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(xm @ p["maa_w1"].astype(x.dtype))           # (B,S,5*r)
    lora = lora.reshape(*lora.shape[:-1], _N_MIX, _LORA_MIX)
    m = jnp.einsum("bsnr,nrd->nbsd", lora, p["maa_w2"].astype(x.dtype))
    m = m + p["mu_rkvwg"].astype(x.dtype)[:, None, None, :]
    return x[None] + (s - x)[None] * m


def _decay(p: Params, xw: jax.Array) -> jax.Array:
    """log-decay (negative), fp32: logw = -exp(w0 + lora_w(xw))."""
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(xw.dtype)) @ p["w_lora_b"].astype(xw.dtype)
    return -jnp.exp(jnp.clip((p["w0"].astype(jnp.float32) + lora.astype(jnp.float32)),
                             -10.0, 8.0))


def _group_norm(p: Params, y: jax.Array, n_heads: int, eps: float = 64e-5) -> jax.Array:
    """Per-head LayerNorm over the flattened (H*N) output (RWKV ln_x)."""
    B, S, D = y.shape
    yh = y.reshape(B, S, n_heads, D // n_heads).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = ((yh - mu) ** 2).mean(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    out = yh.reshape(B, S, D) * p["ln_x_scale"].astype(jnp.float32) + p["ln_x_bias"].astype(jnp.float32)
    return out.astype(y.dtype)


def _wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Exact chunked WKV.  r/k/v: (B,S,H,N); logw fp32 (B,S,H,N); u (H,N);
    state (B,H,N,N) fp32.  Returns (y (B,S,H,N), new_state)."""
    B, S, H, N = r.shape
    L = min(chunk, S)
    n_chunks = -(-S // L)
    pad = n_chunks * L - S
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zpad(r), zpad(k), zpad(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))  # logw=0 -> w=1 (no decay)
    cs = lambda a: a.reshape(B, n_chunks, L, H, N).swapaxes(0, 1)
    rc, kc, vc, wc = cs(r), cs(k), cs(v), cs(logw)

    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)  # strictly lower: tau < t

    def body(S0, xs):
        rb, kb, vb, wb = xs                    # (B,L,H,N)
        rb32, kb32, vb32 = (a.astype(jnp.float32) for a in (rb, kb, vb))
        cum = jnp.cumsum(wb, axis=1)           # inclusive cumsum of log-decay
        cum_excl = cum - wb                    # exclusive
        # intra-chunk: A[t,tau] = sum_n r[t,n] k[tau,n] exp(cum_excl[t]-cum[tau])
        ratio = cum_excl[:, :, None, :, :] - cum[:, None, :, :, :]  # (B,t,tau,H,N)
        ratio = jnp.where(mask[None, :, :, None, None], ratio, -jnp.inf)
        A = jnp.einsum("bthn,bshn,btshn->bhts", rb32, kb32, jnp.exp(ratio))
        diag = jnp.einsum("bthn,hn,bthn->bht", rb32, u.astype(jnp.float32), kb32)
        A = A + jnp.eye(L)[None, None] * diag[..., None]
        y_intra = jnp.einsum("bhts,bshn->bthn", A, vb32)
        # inter-chunk: y += (r ⊙ exp(cum_excl))^T S0
        y_inter = jnp.einsum("bthn,bhnm->bthm", rb32 * jnp.exp(cum_excl), S0)
        # state update: S = diag(exp(cum_L)) S0 + sum_tau (k ⊙ exp(cum_L - cum_tau)) v^T
        decay_all = jnp.exp(cum[:, -1])        # (B,H,N)
        k_scaled = kb32 * jnp.exp(cum[:, -1][:, None] - cum)
        S_new = decay_all[..., None] * S0 + jnp.einsum("bthn,bthm->bhnm", k_scaled, vb32)
        return S_new, (y_intra + y_inter).astype(r.dtype)

    state, ys = jax.lax.scan(body, state, (rc, kc, vc, wc))
    y = ys.swapaxes(0, 1).reshape(B, n_chunks * L, H, N)
    return y[:, :S], state


def _wkv_step(r, k, v, logw, u, state):
    """Single decode step. r/k/v/logw: (B,H,N); state (B,H,N,N) fp32."""
    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    kv = k32[..., :, None] * v32[..., None, :]             # (B,H,N,N)
    y = jnp.einsum("bhn,bhnm->bhm", r32, state + u.astype(jnp.float32)[None, :, :, None] * kv)
    state = jnp.exp(logw)[..., None] * state + kv
    return y.astype(r.dtype), state


def apply_time_mix(
    p: Params, x: jax.Array, cfg: ModelConfig,
    state: Optional[Dict[str, jax.Array]] = None,
    chunk: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B,S,D).  ``state`` = {"prev": (B,D), "wkv": (B,H,N,N) fp32} for decode."""
    chunk = chunk or cfg.rwkv_chunk
    B, S, D = x.shape
    N = cfg.rwkv_head_dim
    H = D // N
    prev = state["prev"] if state else None
    s = _token_shift(x, prev)
    xr, xk, xv, xw, xg = _ddlerp(p, x, s)
    r = (xr @ p["w_r"].astype(x.dtype)).reshape(B, S, H, N)
    k = (xk @ p["w_k"].astype(x.dtype)).reshape(B, S, H, N)
    v = (xv @ p["w_v"].astype(x.dtype)).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ p["w_g"].astype(x.dtype))
    logw = _decay(p, xw).reshape(B, S, H, N)

    wkv0 = state["wkv"] if state else jnp.zeros((B, H, N, N), jnp.float32)
    if S == 1:
        y, wkv = _wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], p["u"], wkv0)
        y = y[:, None]
    elif cfg.kernel_impl == "pallas":
        from ..kernels import ops as kops
        y, wkv = kops.rwkv6_scan(r, k, v, logw, p["u"], wkv0, chunk=chunk)
    else:
        y, wkv = _wkv_chunked(r, k, v, logw, p["u"], wkv0, chunk)

    y = _group_norm(p, y.reshape(B, S, D), H) * g
    out = y @ p["w_o"].astype(x.dtype)
    return out, {"prev": x[:, -1], "wkv": wkv}


def apply_channel_mix(
    p: Params, x: jax.Array, cfg: ModelConfig,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    prev = state["prev"] if state else None
    s = _token_shift(x, prev)
    xk = x + (s - x) * p["mu_k"].astype(x.dtype)
    xr = x + (s - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(x.dtype)))
    v = k @ p["w_v"].astype(x.dtype)
    rgate = jax.nn.sigmoid(xr @ p["w_r"].astype(x.dtype))
    return rgate * v, {"prev": x[:, -1]}


def init_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    """Per-layer decode state (O(1) in sequence length — the long_500k enabler)."""
    D = cfg.d_model
    N = cfg.rwkv_head_dim
    H = D // N
    adt = jnp.dtype(cfg.activation_dtype)
    return {
        "tm": {"prev": jnp.zeros((batch, D), adt), "wkv": jnp.zeros((batch, H, N, N), jnp.float32)},
        "cm": {"prev": jnp.zeros((batch, D), adt)},
    }
