"""Stack assembly: segments of repeated layer groups, scanned with lax.scan.

A *segment* is (block_types, n_repeats): dense models are one segment
(("attention",), L); RecurrentGemma's 1:2 hybrid is
(("rglru","rglru","local_attn"), L//3) plus a remainder segment.  Per-segment
parameters are stacked along a leading repeat axis so the whole stack lowers
as one scanned HLO body — compile time and HLO size stay O(period), not O(L).

Block kinds:
  attention   — GQA/MQA (+optional SWA) + gated MLP (or MoE for moe family)
  local_attn  — sliding-window attention + MLP (hybrid)
  rglru       — RG-LRU temporal block + MLP (hybrid)
  rwkv6       — RWKV-6 time-mix + channel-mix
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import kvcache as kv
from . import layers as L
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv6 as rwkv6_mod

Params = Dict[str, Any]


# -- static structure -------------------------------------------------------------

def segment_specs(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    pattern = cfg.pattern_for_layers()
    period = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_full = len(pattern) // period
    segs: List[Tuple[Tuple[str, ...], int]] = []
    if n_full:
        segs.append((tuple(pattern[:period]), n_full))
    rem = len(pattern) - n_full * period
    if rem:
        segs.append((tuple(pattern[n_full * period:]), 1))
    return segs


# -- init ----------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, block_type: str) -> Params:
    ks = jax.random.split(key, 4)
    if block_type == "rwkv6":
        return {
            "norm1": L.init_norm(cfg.d_model, cfg),
            "tm": rwkv6_mod.init_time_mix(ks[0], cfg),
            "norm2": L.init_norm(cfg.d_model, cfg),
            "cm": rwkv6_mod.init_channel_mix(ks[1], cfg),
        }
    if block_type == "rglru":
        return {
            "norm1": L.init_norm(cfg.d_model, cfg),
            "rglru": rglru_mod.init_rglru_block(ks[0], cfg),
            "norm2": L.init_norm(cfg.d_model, cfg),
            "mlp": L.init_mlp(ks[1], cfg),
        }
    # attention / local_attn
    p: Params = {
        "norm1": L.init_norm(cfg.d_model, cfg),
        "attn": L.init_attention(ks[0], cfg),
        "norm2": L.init_norm(cfg.d_model, cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe_layer(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def init_stack(key, cfg: ModelConfig) -> List[Params]:
    """Per-segment stacked params: list aligned with segment_specs(cfg)."""
    segs = segment_specs(cfg)
    out: List[Params] = []
    for si, (types, n) in enumerate(segs):
        seg_blocks = []
        for bi, btype in enumerate(types):
            per_repeat = [
                _init_block(jax.random.fold_in(key, si * 10_000 + bi * 100 + r), cfg, btype)
                for r in range(n)
            ]
            seg_blocks.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_repeat))
        out.append({"blocks": seg_blocks})
    return out


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> List[Any]:
    """Decode caches, segment-aligned, stacked along the repeat axis."""
    segs = segment_specs(cfg)
    caches = []
    for types, n in segs:
        seg = []
        for btype in types:
            one = kv.init_block_state(cfg, _state_kind(btype), batch, max_len)
            seg.append(jax.tree_util.tree_map(lambda x: jnp.stack([x] * n), one))
        caches.append(seg)
    return caches


def _state_kind(btype: str) -> str:
    return btype


# -- forward ---------------------------------------------------------------------------

def _apply_block(
    bp: Params, cfg: ModelConfig, btype: str, x: jax.Array,
    positions: jax.Array, state: Optional[Any], mode: str,
) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x_out, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if btype == "rwkv6":
        h, tm_state = rwkv6_mod.apply_time_mix(
            bp["tm"], L.apply_norm(bp["norm1"], x, cfg), cfg,
            state["tm"] if state else None)
        x = x + h
        h, cm_state = rwkv6_mod.apply_channel_mix(
            bp["cm"], L.apply_norm(bp["norm2"], x, cfg), cfg,
            state["cm"] if state else None)
        x = x + h
        return x, {"tm": tm_state, "cm": cm_state}, aux

    if btype == "rglru":
        h, new_state = rglru_mod.apply_rglru_block(
            bp["rglru"], L.apply_norm(bp["norm1"], x, cfg), cfg, state)
        x = x + h
        x = x + L.apply_mlp(bp["mlp"], L.apply_norm(bp["norm2"], x, cfg), cfg)
        return x, new_state, aux

    # attention / local_attn
    window = cfg.sliding_window if (btype == "local_attn" or cfg.sliding_window) else None
    causal = not cfg.encoder_only
    xn = L.apply_norm(bp["norm1"], x, cfg)
    if state is None:  # train: plain self-attention
        h, _ = L.attention(bp["attn"], xn, cfg, positions, causal=causal, window=window)
        new_state = None
    elif mode == "prefill":
        # self-attention over the prompt + write (the tail of) k/v to the cache
        h, (k_new, v_new) = L.attention(bp["attn"], xn, cfg, positions,
                                        causal=causal, window=window)
        new_state = kv.update_attn_cache(state, k_new, v_new, positions)
    else:  # decode: write this step's k/v, then attend against the cache
        q, k_new, v_new = L.project_qkv(bp["attn"], xn, cfg, positions)
        new_state = kv.update_attn_cache(state, k_new, v_new, positions)
        (k_all, v_all), kpos = kv.attn_cache_views(new_state, x.shape[0])
        out = L.attend(q, k_all, v_all, positions, kpos, cfg,
                       causal=causal, window=window)
        B, S_, H, hd = out.shape
        h = out.reshape(B, S_, H * hd) @ bp["attn"]["wo"]
    x = x + h
    xn2 = L.apply_norm(bp["norm2"], x, cfg)
    if cfg.family == "moe":
        h2, aux = moe_mod.apply_moe_layer(bp["moe"], xn2, cfg)
    else:
        h2 = L.apply_mlp(bp["mlp"], xn2, cfg)
    x = x + h2
    return x, new_state, aux


def apply_stack(
    stack: List[Params], cfg: ModelConfig, x: jax.Array, positions: jax.Array,
    caches: Optional[List[Any]] = None, mode: str = "train",
) -> Tuple[jax.Array, Optional[List[Any]], jax.Array]:
    """Run all segments. mode: train | prefill | decode.

    train:   caches must be None; returns (x, None, aux)
    prefill: caches are fresh; returns (x, filled caches, aux)
    decode:  x is (B, 1, D); caches updated in ring fashion
    """
    segs = segment_specs(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Optional[List[Any]] = [] if caches is not None else None

    for si, (types, n) in enumerate(segs):
        seg_params = stack[si]["blocks"]
        seg_caches = caches[si] if caches is not None else [None] * len(types)

        def body(carry, xs):
            from ..dist.sharding import constrain
            xc, aux_c = carry
            blocks = xs[0]
            block_states = xs[1]
            out_states = []
            for bi, btype in enumerate(types):
                st = block_states[bi] if caches is not None else None
                xc, new_st, aux_b = _apply_block(blocks[bi], cfg, btype, xc,
                                                 positions, st, mode)
                xc = constrain(xc)  # pin batch sharding at every block boundary
                aux_c = aux_c + aux_b
                out_states.append(new_st if caches is not None else jnp.zeros(()))
            return (xc, aux_c), tuple(out_states)

        body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body

        (x, aux_total), seg_states_out = jax.lax.scan(
            body_fn, (x, aux_total),
            (seg_params, tuple(seg_caches) if caches is not None
             else tuple(jnp.zeros((n,)) for _ in types)),
        )
        if new_caches is not None:
            new_caches.append(list(seg_states_out))

    return x, new_caches, aux_total
