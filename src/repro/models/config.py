"""Model configuration — one dataclass covering all six assigned families.

Families: dense decoder (llama/gemma/qwen-style), fine-grained MoE, RWKV-6
(attention-free SSM), RecurrentGemma hybrid (RG-LRU + local attention), audio
encoder (HuBERT backbone, stub conv frontend) and VLM (PaliGemma backbone,
stub SigLIP frontend).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

__all__ = ["MoEConfig", "ModelConfig"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # hidden size of each routed expert
    n_shared: int = 0             # always-on shared experts (DeepSeek-MoE)
    capacity_factor: float = 1.25
    group_size: int = 256         # tokens per dispatch group (GShard-style)
    aux_loss_coef: float = 0.01
    router_dtype: str = "float32"
    impl: str = "einsum"          # einsum (GShard one-hot) | scatter (sort-based)


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: Optional[int] = None      # GQA; None -> n_heads; 1 -> MQA
    head_dim: Optional[int] = None        # None -> d_model // n_heads
    activation: str = "swiglu"            # swiglu | geglu | gelu
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None  # SWA width (h2o-danube, rg local attn)
    encoder_only: bool = False            # hubert: bidirectional, no decode
    logit_softcap: Optional[float] = None
    embedding_scale: bool = False         # gemma multiplies embeds by sqrt(d)
    moe: Optional[MoEConfig] = None
    # -- hybrid (recurrentgemma) ------------------------------------------------
    block_pattern: Optional[Tuple[str, ...]] = None  # e.g. ("rglru","rglru","local_attn")
    rglru_d_rnn: Optional[int] = None     # RG-LRU recurrence width (None -> d_model)
    conv1d_width: int = 4
    # -- rwkv6 -------------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 32                  # chunked-WKV block length (L)
    # -- modality frontends (STUBS: precomputed embeddings are the input) -------
    frontend: Optional[str] = None        # None | audio_stub | vision_stub
    frontend_dim: int = 512               # conv-feature / projected-patch width
    n_prefix_embeds: int = 256            # VLM: image patches per sequence
    # -- numerics ------------------------------------------------------------------
    param_dtype: str = "float32"
    activation_dtype: str = "float32"
    remat: bool = False                   # per-layer activation checkpointing
    train_microbatch: int = 1             # gradient-accumulation slices per step
    padded_vocab: Optional[int] = None    # pad embed/head to a shardable size
    opt_moment_dtype: str = "float32"     # AdamW m/v dtype (bf16 halves opt state)
    attn_impl: str = "auto"               # auto | naive | chunked | pallas
    attn_chunk: int = 512                 # q-block for chunked attention
    kernel_impl: str = "jnp"              # jnp | pallas: RWKV6/RG-LRU scan path
    scan_layers: bool = True              # lax.scan over (stacked) layer params
    source: str = ""                      # citation (paper / model card)

    # -- derived -----------------------------------------------------------------
    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads if self.n_kv_heads is not None else self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    @property
    def supports_long_context(self) -> bool:
        """True if decode state is O(1)/O(window) in sequence length."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def pattern_for_layers(self) -> List[str]:
        """Resolved per-layer block type list of length n_layers."""
        if self.family == "ssm":
            return ["rwkv6"] * self.n_layers
        if self.block_pattern:
            pat = list(self.block_pattern)
            return [pat[i % len(pat)] for i in range(self.n_layers)]
        return ["attention"] * self.n_layers

    def validate(self) -> "ModelConfig":
        if self.family not in ("dense", "moe", "ssm", "hybrid", "audio", "vlm"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family == "moe" and self.moe is None:
            raise ValueError("moe family requires moe config")
        if self.n_heads and self.kv_heads and self.n_heads % self.kv_heads != 0:
            raise ValueError(f"n_heads={self.n_heads} not divisible by kv={self.kv_heads}")
        if self.family == "hybrid" and not self.block_pattern:
            raise ValueError("hybrid family requires block_pattern")
        if self.encoder_only and self.family not in ("audio", "dense"):
            raise ValueError("encoder_only supported for audio/dense")
        return self

    def reduced(self, n_layers: int = 2, d_model: int = 256, n_experts: int = 4,
                vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family (small dims, same topology)."""
        d_model = min(d_model, self.d_model)
        n_heads = max(1, min(self.n_heads, d_model // 64))
        kv = max(1, min(self.kv_heads, n_heads))
        while n_heads % kv:
            kv -= 1
        changes = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=kv,
            head_dim=64 if self.head_dim else None,
            d_ff=max(64, d_model * 2),
            vocab_size=min(vocab, self.vocab_size),
            rglru_d_rnn=d_model if self.rglru_d_rnn else None,
            frontend_dim=min(self.frontend_dim, 64),
            n_prefix_embeds=min(self.n_prefix_embeds, 8),
            remat=False,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(n_experts, self.moe.n_experts),
                top_k=min(self.moe.top_k, min(n_experts, self.moe.n_experts)),
                d_expert=64,
                group_size=32,
            )
        if self.block_pattern and n_layers < len(self.block_pattern):
            changes["n_layers"] = len(self.block_pattern)
        return dataclasses.replace(self, **changes).validate()
