"""Model entry points: init / train forward / prefill / decode for every family.

Modality frontends are STUBS by assignment: ``audio`` consumes precomputed
conv-feature frames (B, S, frontend_dim) through a linear projection (the
HuBERT conv codec itself is out of scope); ``vlm`` consumes precomputed SigLIP
patch embeddings (B, P, frontend_dim) through a projector, prepended to the
text token embeddings (PaliGemma's prefix-LM layout).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import transformer as T

Params = Dict[str, Any]


# -- init ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {
        "embed": L.init_embedding(ks[0], cfg),
        "stack": T.init_stack(ks[1], cfg),
        "final_norm": L.init_norm(cfg.d_model, cfg),
    }
    head = L.init_lm_head(ks[2], cfg)
    if head is not None:
        p["lm_head"] = head
    if cfg.frontend == "audio_stub":
        p["frontend"] = {"proj": L._dense_init(ks[3], (cfg.frontend_dim, cfg.d_model),
                                               jnp.dtype(cfg.param_dtype))}
    elif cfg.frontend == "vision_stub":
        p["frontend"] = {"proj": L._dense_init(ks[3], (cfg.frontend_dim, cfg.d_model),
                                               jnp.dtype(cfg.param_dtype))}
    return p


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# -- input embedding ---------------------------------------------------------------

def _embed_inputs(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    from ..dist.sharding import constrain
    adt = jnp.dtype(cfg.activation_dtype)
    if cfg.frontend == "audio_stub":
        x = batch["features"].astype(adt) @ params["frontend"]["proj"].astype(adt)
    elif cfg.frontend == "vision_stub":
        img = batch["patch_embeds"].astype(adt) @ params["frontend"]["proj"].astype(adt)
        txt = L.embed_tokens(params["embed"], batch["tokens"], cfg)
        x = jnp.concatenate([img, txt], axis=1)
    else:
        x = L.embed_tokens(params["embed"], batch["tokens"], cfg)
    return constrain(x)


def _logits(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.lm_logits(params["embed"], params.get("lm_head"), x, cfg)


# -- losses -------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Token-mean CE in fp32. Returns (loss, accuracy)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom, (correct * mask).sum() / denom


# -- forward passes -----------------------------------------------------------------

def forward_train(params: Params, batch: Dict[str, jax.Array],
                  cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (total_loss, metrics).  batch needs family-appropriate inputs
    plus "labels" (and optional "loss_mask")."""
    x = _embed_inputs(params, batch, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, _, aux = T.apply_stack(params["stack"], cfg, x, positions, None, mode="train")
    logits = _logits(params, x, cfg)

    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.frontend == "vision_stub":
        # loss only over the text region (after the image prefix)
        P = batch["patch_embeds"].shape[1]
        logits = logits[:, P:]
    ce, acc = cross_entropy(logits, labels, mask)
    aux_coef = cfg.moe.aux_loss_coef if cfg.moe else 0.0
    loss = ce + aux_coef * aux
    return loss, {"loss": ce, "aux_loss": aux, "accuracy": acc}


def forward_encode(params: Params, batch: Dict[str, jax.Array],
                   cfg: ModelConfig) -> jax.Array:
    """Encoder-only / no-cache forward returning full logits (hubert prefill)."""
    x = _embed_inputs(params, batch, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, _, _ = T.apply_stack(params["stack"], cfg, x, positions, None, mode="train")
    return _logits(params, x, cfg)


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            max_len: int) -> Tuple[jax.Array, List[Any]]:
    """Process a prompt, fill caches sized ``max_len``; return (last-token
    logits (B, V), caches)."""
    x = _embed_inputs(params, batch, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    caches = T.init_caches(cfg, B, max_len)
    x, caches, _ = T.apply_stack(params["stack"], cfg, x, positions, caches,
                                 mode="prefill")
    logits = _logits(params, x[:, -1:], cfg)
    return logits[:, 0], caches


def decode_step(params: Params, caches: List[Any], tokens: jax.Array,
                pos: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, List[Any]]:
    """One synchronized decode step.  tokens (B,) int32, pos scalar int32.
    Returns (logits (B, V), updated caches)."""
    x = L.embed_tokens(params["embed"], tokens[:, None], cfg)
    B = x.shape[0]
    positions = jnp.broadcast_to(pos.astype(jnp.int32)[None, None], (B, 1))
    x, caches, _ = T.apply_stack(params["stack"], cfg, x, positions, caches,
                                 mode="decode")
    logits = _logits(params, x, cfg)
    return logits[:, 0], caches
