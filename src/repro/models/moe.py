"""Fine-grained Mixture-of-Experts (DeepSeek-MoE / Granite-MoE style).

TPU-native GShard/Switch dispatch: tokens are split into groups; within each
group a capacity-bounded one-hot dispatch tensor routes tokens to experts via
einsum.  When the expert axis is sharded over ``model`` (expert parallelism)
GSPMD lowers the dispatch/combine einsums to all-to-alls — the collective
pattern the roofline analysis watches.

Routing: softmax over all experts -> top-k -> renormalize over the selected k
(DeepSeek-MoE convention).  Shared experts (always-on) are a plain dense MLP
added to the routed output.  Aux load-balance loss is Switch-style
``E * sum_e f_e * p_e``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .layers import _dense_init, apply_mlp, init_mlp

Params = Dict[str, Any]


def init_moe_layer(key, cfg: ModelConfig) -> Params:
    assert cfg.moe is not None
    moe = cfg.moe
    dtype = jnp.dtype(cfg.param_dtype)
    D, E, Fe = cfg.d_model, moe.n_experts, moe.d_expert
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": _dense_init(ks[0], (D, E), dtype, scale=0.02),
        "experts": {
            "w_gate": _dense_init(ks[1], (E, D, Fe), dtype),
            "w_up": _dense_init(ks[2], (E, D, Fe), dtype),
            "w_down": _dense_init(ks[3], (E, Fe, D), dtype),
        },
    }
    if moe.n_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=moe.n_shared * moe.d_expert)
    return p


def _route(logits: jax.Array, moe: MoEConfig) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits (G, S, E) -> (weights (G,S,k), expert_idx (G,S,k), probs (G,S,E))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, moe.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)  # renormalize
    return top_w, top_idx, probs


def _dispatch_tensors(top_w, top_idx, moe: MoEConfig, S: int) -> Tuple[jax.Array, jax.Array]:
    """Build capacity-bounded dispatch/combine tensors.

    top_w/top_idx: (G, S, k).  Returns:
      dispatch (G, S, E, C) one-hot float — token s of group g goes to slot c of expert e
      combine  (G, S, E, C) — dispatch * routing weight
    Tokens overflowing expert capacity C are dropped (standard GShard).
    """
    E = moe.n_experts
    C = max(1, int(math.ceil(S * moe.top_k / E * moe.capacity_factor)))
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)          # (G,S,k,E)
    # position of each (token, k) among that expert's tokens, in token order
    flat = onehot.reshape(onehot.shape[0], -1, E)                    # (G, S*k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                            # (G, S*k, E)
    pos = pos.reshape(onehot.shape)                                  # (G,S,k,E)
    in_cap = (pos < C).astype(jnp.float32) * onehot
    slot = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)  # (G,S,k,E,C)
    disp_k = in_cap[..., None] * slot                                # (G,S,k,E,C)
    dispatch = disp_k.sum(2)                                         # (G,S,E,C)
    combine = (disp_k * top_w[..., None, None]).sum(2)               # (G,S,E,C)
    return dispatch, combine


def _rank_within_expert(e_flat: jax.Array) -> jax.Array:
    """e_flat (G, N) expert ids -> rank of each token among same-expert tokens.

    Sort-based: O(N log N) with (G, N) intermediates only — avoids the
    (G, N, E) one-hot cumsum of the einsum path entirely."""
    G, N = e_flat.shape
    order = jnp.argsort(e_flat, axis=1, stable=True)
    es = jnp.take_along_axis(e_flat, order, axis=1)
    idx = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None], (G, N))
    first = jnp.concatenate(
        [jnp.ones((G, 1), bool), es[:, 1:] != es[:, :-1]], axis=1)
    seg_start = jax.lax.cummax(jnp.where(first, idx, 0), axis=1)
    rank_sorted = idx - seg_start
    inv = jnp.argsort(order, axis=1)  # scatter ranks back to token order
    return jnp.take_along_axis(rank_sorted, inv, axis=1)


def _apply_moe_scatter(p: Params, xg: jax.Array, cfg: ModelConfig
                       ) -> Tuple[jax.Array, jax.Array]:
    """Sort/scatter dispatch (§Perf iteration): no (G,S,E,C) one-hot tensors.

    xg (G, S, D) -> (out (G, S, D), aux).  Token slots are computed by ranking
    tokens within their expert (sort-based), then a batched scatter builds the
    (G, E*C, D) expert buffers directly and a gather applies the combine
    weights.  Buffer cost is O(tokens * k * cf * D) — the expert-input tensor
    that any capacity MoE needs — instead of O(tokens * E * C) dispatch masks.
    """
    moe = cfg.moe
    G, S, D = xg.shape
    E, k = moe.n_experts, moe.top_k
    C = max(1, int(math.ceil(S * k / E * moe.capacity_factor)))
    dtype = xg.dtype

    router_dtype = jnp.dtype(moe.router_dtype)
    logits = xg.astype(router_dtype) @ p["router"].astype(router_dtype)
    top_w, top_idx, probs = _route(logits, moe)                 # (G,S,k) x2, (G,S,E)

    e_flat = top_idx.reshape(G, S * k).astype(jnp.int32)
    rank = _rank_within_expert(e_flat)                          # (G, S*k)
    keep = rank < C
    slot = jnp.where(keep, e_flat * C + rank, E * C)            # trash slot E*C

    x_rep = jnp.repeat(xg, k, axis=1)                           # (G, S*k, D)

    def scatter_one(slots, xr):
        return jnp.zeros((E * C + 1, D), dtype).at[slots].set(xr)

    buf = jax.vmap(scatter_one)(slot, x_rep)                    # (G, E*C+1, D)
    expert_in = buf[:, :E * C].reshape(G, E, C, D)

    we = p["experts"]
    h_gate = jnp.einsum("gecd,edf->gecf", expert_in, we["w_gate"].astype(dtype))
    h_up = jnp.einsum("gecd,edf->gecf", expert_in, we["w_up"].astype(dtype))
    act = jax.nn.silu(h_gate) if cfg.activation == "swiglu" else \
        jax.nn.gelu(h_gate, approximate=True)
    expert_out = jnp.einsum("gecf,efd->gecd", act * h_up, we["w_down"].astype(dtype))

    out_flat = jnp.concatenate(
        [expert_out.reshape(G, E * C, D), jnp.zeros((G, 1, D), dtype)], axis=1)
    y_k = jax.vmap(lambda of, sl: of[sl])(out_flat, slot)       # (G, S*k, D)
    y = (y_k.reshape(G, S, k, D)
         * top_w.reshape(G, S, k, 1).astype(dtype)).sum(axis=2)

    # aux load-balance: dispatched fraction per expert via scatter-add counts
    counts = jnp.zeros((G, E), jnp.float32).at[
        jnp.arange(G)[:, None], e_flat].add(keep.astype(jnp.float32))
    f = counts / (S * 1.0)
    pbar = probs.mean(1)
    aux = moe.n_experts * jnp.mean(jnp.sum(f * pbar, axis=-1))
    return y, aux.astype(jnp.float32)


def apply_moe_layer(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    moe = cfg.moe
    B, S, D = x.shape
    g = moe.group_size
    n_tokens = B * S
    n_groups = max(1, n_tokens // g)
    if n_tokens % g:
        # pad token count to a multiple of the group size
        pad = n_groups * g + (g if n_tokens > n_groups * g else 0) - n_tokens
        xt = jnp.pad(x.reshape(n_tokens, D), ((0, pad), (0, 0)))
        n_groups = xt.shape[0] // g
    else:
        xt = x.reshape(n_tokens, D)
        pad = 0
    xg = xt.reshape(n_groups, g, D)

    if moe.impl == "scatter":
        routed, aux = _apply_moe_scatter(p, xg, cfg)
    else:
        router_dtype = jnp.dtype(moe.router_dtype)
        logits = (xg.astype(router_dtype) @ p["router"].astype(router_dtype))  # (G,S,E)
        top_w, top_idx, probs = _route(logits, moe)
        dispatch, combine = _dispatch_tensors(top_w, top_idx, moe, g)

        # aux load-balance loss (Switch): E * mean_e[f_e * p_e]
        f = dispatch.sum((1, 3)) / g                       # (G, E) fraction dispatched
        pbar = probs.mean(1)                               # (G, E)
        aux = moe.n_experts * jnp.mean(jnp.sum(f * pbar, axis=-1))

        dtype = xg.dtype
        expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(dtype), xg)   # (E,G,C,D)
        we = p["experts"]
        h_gate = jnp.einsum("egcd,edf->egcf", expert_in, we["w_gate"].astype(dtype))
        h_up = jnp.einsum("egcd,edf->egcf", expert_in, we["w_up"].astype(dtype))
        act = jax.nn.silu(h_gate) if cfg.activation == "swiglu" else jax.nn.gelu(h_gate, approximate=True)
        expert_out = jnp.einsum("egcf,efd->egcd", act * h_up, we["w_down"].astype(dtype))
        routed = jnp.einsum("gsec,egcd->gsd", combine.astype(dtype), expert_out)  # (G,S,D)

    out = routed.reshape(-1, D)
    if pad:
        out = out[:n_tokens]
    out = out.reshape(B, S, D)

    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, cfg)
    return out, aux.astype(jnp.float32)
