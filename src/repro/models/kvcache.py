"""Decode-time state: full KV caches, sliding-window (ring) caches, recurrent
states.  Decode is synchronized across the batch (one global position), the
standard TPU serving layout: caches are dense arrays indexed by a scalar step.

Cache pytrees are built per *segment* (see transformer.py): leading axis is the
segment's repeat count so they scan together with the stacked layer params.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import rglru as rglru_mod
from . import rwkv6 as rwkv6_mod

Params = Dict[str, Any]


def init_block_state(cfg: ModelConfig, block_type: str, batch: int,
                     max_len: int) -> Optional[Dict[str, jax.Array]]:
    """Fresh decode state for one block. max_len = cache capacity (full attn)
    or ignored (window/recurrent)."""
    adt = jnp.dtype(cfg.activation_dtype)
    K, hd = cfg.kv_heads, cfg.hd
    if block_type == "attention":
        cap = max_len if cfg.sliding_window is None else min(cfg.sliding_window, max_len)
        return {
            "k": jnp.zeros((batch, cap, K, hd), adt),
            "v": jnp.zeros((batch, cap, K, hd), adt),
            "kpos": jnp.full((cap,), -1, jnp.int32),
        }
    if block_type == "local_attn":
        cap = min(cfg.sliding_window or 2048, max_len)
        return {
            "k": jnp.zeros((batch, cap, K, hd), adt),
            "v": jnp.zeros((batch, cap, K, hd), adt),
            "kpos": jnp.full((cap,), -1, jnp.int32),
        }
    if block_type == "rglru":
        return rglru_mod.init_state(cfg, batch)
    if block_type == "rwkv6":
        return rwkv6_mod.init_state(cfg, batch)
    raise ValueError(f"unknown block type {block_type}")


def update_attn_cache(cache: Dict[str, jax.Array], k_new: jax.Array, v_new: jax.Array,
                      positions: jax.Array) -> Dict[str, jax.Array]:
    """Write S_new freshly-computed (post-RoPE) k/v at their positions.

    Ring-buffer semantics: slot = position % capacity.  For a full cache the
    capacity >= max sequence length so slots never collide; for a sliding
    window the oldest entries are overwritten — exactly the tokens that fell
    out of the window.  When writing more tokens than the capacity (window
    prefill) only the last ``cap`` are written, keeping scatter indices unique
    (the earlier ones would be overwritten anyway).
    """
    cap = cache["k"].shape[1]
    S = k_new.shape[1]
    if S >= cap:
        k_new, v_new = k_new[:, -cap:], v_new[:, -cap:]
        pos_vec = positions[0, -cap:]
    else:
        pos_vec = positions[0]  # synchronized decode: same positions per batch row
    slots = pos_vec % cap
    k = cache["k"].at[:, slots].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[:, slots].set(v_new.astype(cache["v"].dtype))
    kpos = cache["kpos"].at[slots].set(pos_vec)
    return {"k": k, "v": v, "kpos": kpos}


def attn_cache_views(cache: Dict[str, jax.Array], batch: int) -> Tuple[Tuple[jax.Array, jax.Array], jax.Array]:
    """Return ((k_all, v_all), k_positions (B, cap)) for attention()."""
    kpos = jnp.broadcast_to(cache["kpos"][None, :], (batch, cache["kpos"].shape[0]))
    return (cache["k"], cache["v"]), kpos
