"""RG-LRU recurrent block — RecurrentGemma / Griffin (arXiv:2402.19427).

Temporal block (recurrent variant):
    gate branch:      g = GeLU(x @ w_gate)
    recurrent branch: u = x @ w_x -> causal depthwise conv1d(width 4) -> RG-LRU
    output:           (g * h) @ w_out

RG-LRU:  r_t = sigmoid(x W_a + b_a), i_t = sigmoid(x W_i + b_i)
         log a_t = -c * softplus(lambda) * r_t            (c = 8)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training evaluates the linear recurrence with ``jax.lax.associative_scan``
(log-depth — the TPU-native choice for a 4k..512k sequence); decode is the
single step.  Griffin's block-diagonal gate matrices are implemented dense
(adaptation noted in DESIGN.md — dense is MXU-friendlier at these widths).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense_init

Params = Dict[str, Any]

_C = 8.0  # Griffin's fixed gate sharpness


def init_rglru_block(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    R = cfg.rglru_d_rnn or D
    W = cfg.conv1d_width
    ks = jax.random.split(key, 6)
    # lambda init so that a^c = exp(-c*softplus(l)) is spread in (0.9, 0.999)
    lam = jax.random.uniform(ks[5], (R,), minval=math.log(math.exp(0.001) - 1) / 1,
                             maxval=math.log(math.exp(0.1) - 1))
    return {
        "w_gate": _dense_init(ks[0], (D, R), dtype),
        "w_x": _dense_init(ks[1], (D, R), dtype),
        "w_out": _dense_init(ks[2], (R, D), dtype),
        "conv_w": (jax.random.normal(ks[3], (W, R)) / math.sqrt(W)).astype(dtype),
        "conv_b": jnp.zeros((R,), dtype),
        "gates": {
            "w_a": _dense_init(ks[4], (R, R), dtype, scale=1.0 / math.sqrt(R)),
            "b_a": jnp.zeros((R,), dtype),
            "w_i": _dense_init(jax.random.fold_in(ks[4], 1), (R, R), dtype,
                               scale=1.0 / math.sqrt(R)),
            "b_i": jnp.zeros((R,), dtype),
        },
        "lam": lam.astype(jnp.float32),
    }


def _causal_conv1d(p: Params, u: jax.Array,
                   conv_state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. u (B,S,R); conv_state (B,W-1,R) carries history."""
    W = p["conv_w"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    xext = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)  # (B, S+W-1, R)
    out = sum(
        xext[:, i : i + u.shape[1]] * p["conv_w"][i].astype(u.dtype)
        for i in range(W)
    ) + p["conv_b"].astype(u.dtype)
    return out, xext[:, -(W - 1):]


def _rglru_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array]) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1.  fp32."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply_rglru_block(
    p: Params, x: jax.Array, cfg: ModelConfig,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B,S,D) -> (out (B,S,D), new_state {"h": (B,R) fp32, "conv": (B,W-1,R)})."""
    gate = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype), approximate=True)
    u = x @ p["w_x"].astype(x.dtype)
    u, conv_state = _causal_conv1d(p, u, state["conv"] if state else None)

    u32 = u.astype(jnp.float32)
    g = p["gates"]
    r = jax.nn.sigmoid(u32 @ g["w_a"].astype(jnp.float32) + g["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(u32 @ g["w_i"].astype(jnp.float32) + g["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r                  # (B,S,R) fp32
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u32)

    h0 = state["h"] if state else None
    if x.shape[1] == 1:
        h_prev = h0 if h0 is not None else jnp.zeros_like(gated_in[:, 0])
        h_last = a[:, 0] * h_prev + gated_in[:, 0]
        h = h_last[:, None]
    elif cfg.kernel_impl == "pallas":
        from ..kernels import ops as kops
        h = kops.rglru_scan(a, gated_in, h0)
        h_last = h[:, -1]
    else:
        h = _rglru_scan(a, gated_in, h0)
        h_last = h[:, -1]

    out = (gate * h.astype(x.dtype)) @ p["w_out"].astype(x.dtype)
    return out, {"h": h_last, "conv": conv_state}


def init_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    R = cfg.rglru_d_rnn or cfg.d_model
    adt = jnp.dtype(cfg.activation_dtype)
    return {
        "h": jnp.zeros((batch, R), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, R), adt),
    }
