from .config import ModelConfig, MoEConfig
from .model import (cross_entropy, decode_step, forward_encode, forward_train,
                    init_params, param_count, prefill)
from .transformer import apply_stack, init_caches, init_stack, segment_specs
