"""Hardware-aware trial placement (DESIGN.md §11).

SHADHO-style scheduling: instead of a fixed ``devices_per_trial``, the cluster
executor asks a placement policy where each trial should run and how wide its
slice should be, given what is known about the trial's workload (its roofline
profile) and each host's hardware (``HostSpec`` throughputs).

The cost model is the same three-term roofline as ``launch/roofline.py``:

    step_s(n) = max( flops / (n * peak_flops),        # compute, ideal scaling
                     bytes / (n * hbm_bw),            # HBM traffic, sharded
                     coll_bytes * (n-1)/n / link_bw ) # ring all-reduce traffic

Compute and memory shrink with slice width; collective traffic *grows* toward
the ring asymptote — which is exactly why "as wide as fits" is the wrong
default and right-sizing is a real decision.

Workload costs come from, in priority order:
  1. ``trial.config["_cost"]``: explicit {"flops", "bytes", "coll_bytes"}.
  2. ``trial.profile``: the PR 7 hardware profile that rides the result
     stream — its ``roofline_*_s`` seconds are denormalized back to work
     units via the reference hardware constants below.
  3. Nothing known: fall back to the fixed default width.

This module is jax-free (the cluster controller may run where jax is absent);
the reference constants mirror ``launch.mesh.HW`` rather than importing it.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from .hosts import HostAgent, HostSpec

__all__ = ["FixedPlacement", "RooflinePlacement", "estimate_step_s",
           "workload_cost"]

# Mirror of launch.mesh.HW (per-chip): the units trial profiles were measured
# against.  Kept literal so importing placement never pulls in jax.
REF_PEAK_FLOPS_BF16 = 197e12
REF_HBM_BW = 819e9
REF_ICI_BW = 50e9


def workload_cost(trial: Any) -> Optional[Dict[str, float]]:
    """Extract {"flops", "bytes", "coll_bytes"} work units for one step of
    ``trial``, or None when nothing is known yet (first placement of an
    unprofiled trial)."""
    cost = trial.config.get("_cost") if isinstance(trial.config, dict) else None
    if isinstance(cost, dict) and "flops" in cost:
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes", 0.0)),
                "coll_bytes": float(cost.get("coll_bytes", 0.0))}
    prof = getattr(trial, "profile", None)
    if isinstance(prof, dict) and "roofline_compute_s" in prof:
        return {
            "flops": float(prof["roofline_compute_s"]) * REF_PEAK_FLOPS_BF16,
            "bytes": float(prof.get("roofline_memory_s", 0.0)) * REF_HBM_BW,
            "coll_bytes":
                float(prof.get("roofline_collective_s", 0.0)) * REF_ICI_BW,
        }
    return None


def estimate_step_s(cost: Dict[str, float], spec: HostSpec, n: int) -> float:
    """Roofline step-time estimate for this workload on ``n`` devices of
    ``spec``.  ``n >= 1``."""
    n = max(1, int(n))
    compute_s = cost["flops"] / (n * spec.peak_flops)
    memory_s = cost["bytes"] / (n * spec.hbm_bw)
    collective_s = (cost["coll_bytes"] * (n - 1) / n) / spec.link_bw
    return max(compute_s, memory_s, collective_s)


def _widths_upto(cap: int) -> List[int]:
    """Candidate slice widths: powers of two up to ``cap`` (matching how
    sub-meshes shard cleanly), plus ``cap`` itself."""
    out = []
    w = 1
    while w <= cap:
        out.append(w)
        w *= 2
    if out and out[-1] != cap:
        out.append(cap)
    return out


class FixedPlacement:
    """The pre-cluster behavior, host-aware: every trial gets its requested
    width on the host with the most free devices (roster order breaks ties —
    deterministic under VirtualClock)."""

    def __init__(self, devices_per_trial: Optional[int] = None):
        self.devices_per_trial = devices_per_trial

    def place(self, trial: Any, hosts: Sequence[HostAgent]
              ) -> Optional[Tuple[HostAgent, int]]:
        want = self.devices_per_trial or trial.resources.devices
        best = None
        for host in hosts:
            if not host.alive or not host.pool.can_fit(want):
                continue
            if best is None or host.pool.n_free > best.pool.n_free:
                best = host
        return (best, want) if best is not None else None


class RooflinePlacement:
    """Right-size each trial's slice per host with the roofline cost model.

    For every alive host, every candidate width that currently fits is scored
    by ``estimate_step_s``; the (host, width) with the lowest predicted step
    time wins, preferring the *narrowest* width within ``tolerance`` of the
    best — devices freed by not over-widening one trial run other trials.
    Unprofiled trials fall back to FixedPlacement semantics until their first
    profile arrives (profiles ride the result stream, so a restart or resize
    after warmup places better than the first launch).
    """

    def __init__(self, devices_per_trial: Optional[int] = None,
                 max_devices: int = 64, tolerance: float = 0.05):
        self.fallback = FixedPlacement(devices_per_trial)
        self.max_devices = int(max_devices)
        self.tolerance = float(tolerance)

    def place(self, trial: Any, hosts: Sequence[HostAgent]
              ) -> Optional[Tuple[HostAgent, int]]:
        cost = workload_cost(trial)
        if cost is None:
            return self.fallback.place(trial, hosts)
        best: Optional[Tuple[HostAgent, int]] = None
        best_s = float("inf")
        for host in hosts:
            if not host.alive:
                continue
            cap = min(host.pool.largest_free_block(), self.max_devices)
            if cap < 1:
                continue
            for n in _widths_upto(cap):
                s = estimate_step_s(cost, host.spec, n)
                # strictly-better, or same-within-tolerance but narrower
                if (s < best_s * (1.0 - self.tolerance)
                        or (best is not None
                            and s <= best_s * (1.0 + self.tolerance)
                            and n < best[1])):
                    best, best_s = (host, n), min(s, best_s)
        return best
