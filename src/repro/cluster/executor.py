"""ClusterMeshExecutor: trials scheduled across a roster of host agents.

Extends ``ProcessMeshExecutor`` (so ``BusDrivenExecutor``) with the four
things a multi-host tier adds (DESIGN.md §11):

1. **Per-host SlicePools.**  Each ``HostAgent`` owns its device pool and its
   checkpoint spill surface.  The ``_pool_for(trial)`` seam routes every base
   pool operation (acquire, release, elastic resize) to the trial's host —
   the base executor and broker never learn hosts exist.
2. **Cross-host checkpoints.**  Workers save content-addressed keys
   (``cas/<trial>/<sha256>``) into their host's store; the pump fetches the
   payload to the controller store (digest-verified) *before* adoption, so a
   checkpoint survives its host and a restart on any other host restores it.
3. **Host failure domains.**  Frame traffic and heartbeats stamp
   ``clock.monotonic()`` ages; a host silent past ``host_timeout`` gets
   HEARTBEAT_MISSED on every resident trial, then eviction: every worker is
   killed, every trial errored — restart budgeting is the trial's ordinary
   ``max_failures``, so a host loss is N single-trial failures, not a special
   path.  Framing corruption (``FramingError``) escalates to the same
   eviction: a host spewing garbage cannot be trusted for any resident trial.
4. **Hardware-aware placement.**  ``RooflinePlacement`` right-sizes each
   trial's slice per host from its measured roofline profile (falling back
   to the requested width until a profile arrives).

Two transports, one pump:

- ``transport="socket"``: real worker processes dial back over TCP
  (``cluster.worker``); the pump multiplexes their framed sockets and the
  pipe tier's Connections through one ``multiprocessing.connection.wait``.
- ``transport="virtual"``: in-process workers over ``VirtualTransport``
  under an injected VirtualClock; the pump parks on a notification inbox so
  ``repro.testing`` can script host crashes and partitions deterministically
  (``cluster.sim``).
"""
from __future__ import annotations

import os
import queue
import threading
import traceback
import uuid
from typing import Any, Dict, List, Optional, Tuple

from ..core.events import EventType, TrialEvent
from ..core.process_executor import ProcessMeshExecutor, _WorkerHandle
from ..core.resources import Resources
from ..core.trial import Checkpoint, Trial, TrialStatus
from ..core import workers as _w
from .hosts import HostAgent, HostSpec, fetch, parse_hosts
from .placement import FixedPlacement, RooflinePlacement
from .transport import HEARTBEAT, FramingError
from .worker import ClusterListener, SocketProcessWorker

__all__ = ["ClusterMeshExecutor"]


class ClusterMeshExecutor(ProcessMeshExecutor):
    def __init__(
        self,
        trainable_cls_resolver: Optional[Any] = None,
        checkpoint_manager: Optional[Any] = None,
        hosts: Any = 2,
        placement: Any = "roofline",   # "fixed" | "roofline" | policy object
        transport: str = "socket",     # "socket" | "virtual"
        host_timeout: Optional[float] = None,   # silent-host eviction age
        heartbeat_interval: Optional[float] = None,  # child beat cadence
        devices_per_trial: Optional[int] = None,
        total_cpu: float = 64.0,
        slice_pool: Optional[Any] = None,
        **kwargs: Any,
    ):
        if slice_pool is not None:
            raise ValueError(
                "the cluster tier owns one SlicePool per host; size the "
                "roster via hosts=..., not slice_pool=")
        specs = parse_hosts(hosts)
        # Every cluster field the pump/monitor threads may touch must exist
        # BEFORE super().__init__ — both threads start inside it.
        self.hosts: Dict[str, HostAgent] = {}
        self._host_of: Dict[str, HostAgent] = {}
        self._evict_lock = threading.Lock()
        self.transport_kind = transport
        self.n_host_evictions = 0
        self._inbox: "queue.Queue" = queue.Queue()  # virtual pump wake-ups
        self._attach_lock = threading.Lock()
        self._pending_tr: Dict[str, Any] = {}  # dialed in before start_trial won
        self._listener: Optional[ClusterListener] = None
        self._host_timeout = 0.0
        self._hb_interval = 0.0
        self._host_spill_root: Optional[str] = None
        self.sim = None  # cluster.sim.SimFleet attaches here (virtual tier)
        total_devices = sum(s.devices for s in specs)
        kwargs.pop("total_devices", None)  # roster defines capacity
        super().__init__(trainable_cls_resolver, checkpoint_manager,
                         total_cpu=total_cpu, total_devices=total_devices,
                         slice_pool=None, **kwargs)
        self._host_timeout = (
            float(host_timeout) if host_timeout is not None
            else (3.0 * self.heartbeat_timeout
                  if self.heartbeat_timeout > 0 else 0.0))
        self._hb_interval = float(
            heartbeat_interval if heartbeat_interval is not None
            else (min(5.0, self._host_timeout / 4.0)
                  if self._host_timeout > 0 else 5.0))
        self._host_spill_root = os.path.join(self._spill_dir, "hosts")
        for spec in specs:
            self.hosts[spec.name] = HostAgent(
                spec, self.clock, spill_root=self._host_spill_root)
        if placement == "roofline":
            placement = RooflinePlacement(devices_per_trial)
        elif placement == "fixed":
            placement = FixedPlacement(devices_per_trial)
        self._placement = placement
        self._token = uuid.uuid4().hex
        m = self.obs.metrics
        self._m_evict = m.counter("cluster.host_evictions") if m else None
        self._m_fetch = m.histogram("cluster.fetch_us") if m else None
        if transport == "socket":
            self._listener = ClusterListener(
                self._attach_transport, self._token, clock=self.clock)
        elif transport != "virtual":
            raise ValueError(f"unknown cluster transport {transport!r}")

    # -- roster helpers ---------------------------------------------------------------
    def _alive_hosts(self) -> List[HostAgent]:
        return [h for h in self.hosts.values() if h.alive]

    def _pool_for(self, trial: Trial) -> Optional[Any]:
        host = self._host_of.get(trial.trial_id)
        return host.pool if host is not None else None

    def touch_host(self, name: str) -> None:
        """Out-of-band host liveness signal (agent heartbeat / sim fleet)."""
        host = self.hosts.get(name)
        if host is not None:
            host.touch(self.clock.monotonic())

    def host_state(self) -> Dict[str, Dict[str, Any]]:
        """Per-host snapshot for the flight recorder / introspection."""
        return {
            name: {
                "alive": h.alive,
                "devices": h.spec.devices,
                "free": h.pool.n_free,
                "utilization": round(h.pool.utilization(), 4),
                "fragments": h.pool.fragments(),
                "trials": sorted(h.trials),
                "evicted_reason": h.evicted_reason,
            }
            for name, h in sorted(self.hosts.items())
        }

    # -- placement --------------------------------------------------------------------
    def has_resources(self, trial: Trial) -> bool:
        choice = self._placement.place(trial, self._alive_hosts())
        if choice is None:
            return False
        _, n = choice
        res = (trial.resources if n == trial.resources.devices
               else Resources(cpu=trial.resources.cpu, devices=n))
        return self.accountant.has_room(res)

    def _acquire_slice(self, trial: Trial) -> None:
        choice = self._placement.place(trial, self._alive_hosts())
        if choice is None:
            raise RuntimeError(
                f"no alive host can place {trial.trial_id} "
                f"({trial.resources.devices} devices requested)")
        host, n = choice
        if n != trial.resources.devices:
            # Hardware-aware right-sizing: the cost model, not the request,
            # decides the slice width (SHADHO-style).
            trial.resources = Resources(cpu=trial.resources.cpu, devices=n)
        self._host_of[trial.trial_id] = host
        host.trials.add(trial.trial_id)
        try:
            super()._acquire_slice(trial)  # accountant + host pool via _pool_for
        except Exception:
            host.trials.discard(trial.trial_id)
            self._host_of.pop(trial.trial_id, None)
            raise
        tracer = self.obs.tracer
        if tracer.enabled:
            t0 = tracer.clock.time()
            tracer.record("host.place", trial.trial_id, t0, 0.0,
                          cat="placement", host=host.name,
                          devices=trial.resources.devices)

    def _release(self, trial: Trial) -> None:
        super()._release(trial)  # needs _host_of intact for _pool_for
        host = self._host_of.pop(trial.trial_id, None)
        if host is not None:
            host.trials.discard(trial.trial_id)

    def _worker_config(self, trial: Trial) -> Dict[str, Any]:
        config = super()._worker_config(trial)
        host = self._host_of.get(trial.trial_id)
        if host is not None:
            config["_host"] = host.name
        return config

    # -- lifecycle --------------------------------------------------------------------
    def _spawn_worker(self, factory: Any, trial: Trial, host: HostAgent,
                      restore_key: Optional[str], restore_iter: int) -> Any:
        if self.transport_kind == "virtual":
            from .sim import VirtualWorker
            network = self.sim.network if self.sim is not None else None
            return VirtualWorker(
                self.clock, factory, trial.trial_id,
                self._worker_config(trial), host.store.spill_dir,
                checkpoint_freq=self.checkpoint_freq,
                restore_key=restore_key, restore_iteration=restore_iter,
                trace=self.obs.tracer.enabled, network=network,
                host=host.name, inbox_notify=self._notify_inbox(trial.trial_id))
        return SocketProcessWorker(
            factory, trial.trial_id, self._worker_config(trial),
            host.store.spill_dir, self._listener.address, self._token,
            checkpoint_freq=self.checkpoint_freq,
            restore_key=restore_key, restore_iteration=restore_iter,
            heartbeat_interval=self._hb_interval,
            mp_context=self.mp_context, nice=self.worker_nice,
            trace=self.obs.tracer.enabled)

    def start_trial(self, trial: Trial,
                    checkpoint: Optional[Checkpoint] = None) -> bool:
        if not self.has_resources(trial):
            return False
        try:
            factory = self._resolve_factory(trial.trainable_name)
        except KeyError:
            trial.error = traceback.format_exc()
            trial.set_status(TrialStatus.ERROR)
            return False
        try:
            self._acquire_slice(trial)
        except RuntimeError:
            return False  # roster changed between has_resources and here
        host = self._host_of[trial.trial_id]
        restore_key, restore_iter = None, 0
        if checkpoint is not None:
            try:
                with self._ckpt_lock:
                    restore_key = self.ckpt.export_copy(checkpoint)
                # The snapshot crosses to the target host's spill surface;
                # the child consumes (deletes) the host copy after restoring
                # and READY deletes the controller copy.
                fetch(restore_key, self.ckpt.store, host.store)
            except Exception:  # noqa: BLE001
                self._release(trial)
                trial.error = traceback.format_exc()
                trial.set_status(TrialStatus.ERROR)
                return False
            restore_iter = checkpoint.training_iteration
        try:
            worker = self._spawn_worker(factory, trial, host,
                                        restore_key, restore_iter)
        except Exception:  # noqa: BLE001
            self._release(trial)
            trial.error = traceback.format_exc()
            trial.set_status(TrialStatus.ERROR)
            return False
        ws = _WorkerHandle(trial, worker, self.clock)
        ws.restore_key = restore_key
        ws.restore_ckpt = checkpoint
        with self._attach_lock:
            self._workers[trial.trial_id] = ws
            pending = self._pending_tr.pop(trial.trial_id, None)
        if pending is not None:  # child dialed in before we registered
            worker.attach(pending)
        if self.transport_kind == "virtual":
            # A virtual worker may deliver READY before the handle above is
            # registered; the pump drops notifications for unknown trials, so
            # nudge it to drain anything already queued.
            self._notify_inbox(trial.trial_id)()
        trial.set_status(TrialStatus.RUNNING)
        return True

    # -- socket attach ----------------------------------------------------------------
    def _attach_transport(self, trial_id: str, tr: Any, hello: dict) -> None:
        """Listener thread: bind a dialed-in (or dialed-BACK-in) worker's
        framed transport to its handle; the pump picks it up on the next
        roster snapshot."""
        with self._attach_lock:
            ws = self._workers.get(trial_id)
            if ws is None:
                self._pending_tr[trial_id] = tr
                return
        ws.worker.attach(tr)
        host = self._host_of.get(trial_id)
        if host is not None:
            host.touch(self.clock.monotonic())

    def _notify_inbox(self, trial_id: str):
        def _notify() -> None:
            self._inbox.put(trial_id)
            self.clock.kick(self._inbox)
        return _notify

    # -- pump -------------------------------------------------------------------------
    def _pump(self) -> None:
        if self.transport_kind != "virtual":
            return super()._pump()
        # Virtual pump: no OS objects to select on — endpoints notify this
        # inbox on delivery, and the pump parks through the clock so virtual
        # time can advance around it.
        with self.clock.running():
            while not self._pump_shutdown.is_set():
                tid = self.clock.queue_get(self._inbox, timeout=3600.0)
                if tid is None:
                    continue  # timeout tick; re-check shutdown
                if tid is Ellipsis:
                    return  # shutdown sentinel
                ws = self._workers.get(tid)
                if ws is None:
                    continue
                t = ws.transport
                while (t is not None and not ws.dead
                       and not self._pump_shutdown.is_set() and t.poll(0)):
                    try:
                        msg = t.recv()
                    except (EOFError, OSError) as exc:
                        self._on_recv_error(ws, exc)
                        break
                    try:
                        self._handle_message(ws, msg)
                    except Exception:  # noqa: BLE001 — pump must not die
                        ws.dead = True
                        ws.reply_q.put(("DEAD",))
                        self.bus.publish(TrialEvent(
                            EventType.ERROR, ws.trial.trial_id,
                            error=traceback.format_exc()))

    def _on_recv_error(self, ws: _WorkerHandle, exc: BaseException) -> None:
        if isinstance(exc, FramingError):
            # The stream is corrupt, not closed: the host is emitting bytes
            # we cannot trust, so no trial on it can be trusted either.
            host = self._host_of.get(ws.trial.trial_id)
            if host is not None:
                self._evict_host(host, reason=f"framing corruption: {exc}")
                return
        super()._on_recv_error(ws, exc)

    def _handle_message(self, ws: _WorkerHandle, msg: tuple) -> None:
        kind = msg[0]
        host = self._host_of.get(ws.trial.trial_id)
        if kind == HEARTBEAT[0]:
            if host is not None:
                host.touch(self.clock.monotonic())
            return
        if host is not None:
            # Any protocol frame is proof of host life, not just heartbeats.
            host.touch(self.clock.monotonic())
            if kind == _w.MSG_READY and ws.restore_key:
                # The child consumed the HOST copy; the controller's export
                # copy would otherwise be stranded (the base clears the key
                # without a cluster-side delete).
                try:
                    self.ckpt.store.delete(ws.restore_key)
                except OSError:
                    pass
            elif kind in (_w.MSG_CHECKPOINTED, _w.MSG_SAVED):
                # Content-addressed pull BEFORE adoption: the checkpoint must
                # survive this host.  A digest mismatch raises, and the pump's
                # guard turns that into a worker ERROR (max_failures path).
                self._fetch_to_controller(msg[1], host)
            elif kind == _w.MSG_SPANS:
                msg = (kind, [
                    (n, ts, d, c, p, dict(a or {}, host=host.name))
                    for (n, ts, d, c, p, a) in msg[1]])
        super()._handle_message(ws, msg)

    def _fetch_to_controller(self, key: str, host: HostAgent) -> None:
        if self._m_fetch is not None:
            import time as _time
            p0 = _time.perf_counter()
            fetch(key, host.store, self.ckpt.store)
            self._m_fetch.observe((_time.perf_counter() - p0) * 1e6)
        else:
            fetch(key, host.store, self.ckpt.store)

    def _discard_stale_saved(self, key: str) -> None:
        # Content-addressed keys may be shared with an adopted checkpoint
        # (identical payloads dedupe to one key), so a stale SAVED must NOT
        # delete them; the host-dir cleanup at shutdown reclaims the bytes.
        if not key.startswith("cas/"):
            super()._discard_stale_saved(key)

    # -- host failure domain ----------------------------------------------------------
    def _monitor_tick(self, now: float) -> None:
        self._check_hosts(now)
        super()._monitor_tick(now)

    def _check_hosts(self, now: float) -> None:
        if self._host_timeout <= 0:
            return
        last_traffic: Dict[str, float] = {}
        for ws in list(self._workers.values()):
            if ws.dead:
                continue
            host = self._host_of.get(ws.trial.trial_id)
            t = ws.transport
            if host is None or t is None:
                continue
            last = getattr(t, "last_recv_mono", None)
            if last is not None:
                prev = last_traffic.get(host.name, float("-inf"))
                last_traffic[host.name] = max(prev, last)
        for name, host in list(self.hosts.items()):
            if not host.alive or not host.trials:
                continue
            age = now - max(host.last_seen, last_traffic.get(name, float("-inf")))
            if age > self._host_timeout:
                for trial_id in sorted(host.trials):
                    self.bus.publish(TrialEvent(
                        EventType.HEARTBEAT_MISSED, trial_id,
                        info={"host": name, "silent_s": round(age, 3),
                              "deadline_s": self._host_timeout}))
                self._evict_host(
                    host, reason=f"no heartbeat or frame for {age:.1f}s "
                                 f"(timeout {self._host_timeout:.1f}s)")

    def _evict_host(self, host: HostAgent, reason: str) -> None:
        """Host-level escalation: kill every resident worker, error every
        resident trial.  Each trial's restart is budgeted by its own
        ``max_failures`` — the host failure domain folds into the existing
        per-trial retry machinery rather than introducing a new one."""
        with self._evict_lock:
            if not host.alive:
                return
            host.alive = False
            host.evicted_reason = reason
            host.n_evictions += 1
            self.n_host_evictions += 1
        if self._m_evict is not None:
            self._m_evict.inc()
        for ws in list(self._workers.values()):
            if self._host_of.get(ws.trial.trial_id) is not host or ws.dead:
                continue
            ws.killed = True
            ws.dead = True
            ws.in_step = False
            pid = ws.worker.pid
            try:
                ws.worker.kill(join_timeout=self.join_timeout)
            except Exception:  # noqa: BLE001 — eviction must reap everything
                pass
            ws.reply_q.put(("DEAD",))
            self.n_killed += 1
            self.bus.publish(TrialEvent(
                EventType.KILLED, ws.trial.trial_id,
                info={"host": host.name, "pid": pid,
                      "phase": "host_eviction", "reason": reason}))
            self.bus.publish(TrialEvent(
                EventType.ERROR, ws.trial.trial_id,
                error=(f"host {host.name} evicted ({reason}); worker killed, "
                       "restart from the last fetched checkpoint is governed "
                       "by max_failures")))

    def fail_host(self, name: str, reason: str = "scripted host crash") -> None:
        """Abrupt host death (the simulated fleet's crash primitive): the
        host goes dark and every worker link drops with EOF — the pump's
        ordinary worker-death path errors each trial, exactly as a real
        host's processes vanishing would."""
        host = self.hosts.get(name)
        with self._evict_lock:
            if host is None or not host.alive:
                return
            host.alive = False
            host.evicted_reason = reason
            self.n_host_evictions += 1
        if self._m_evict is not None:
            self._m_evict.inc()
        for ws in list(self._workers.values()):
            if self._host_of.get(ws.trial.trial_id) is not host or ws.dead:
                continue
            die = getattr(ws.worker, "die", None)
            if die is not None:
                die()  # virtual: closes the link, parent sees EOF
            else:
                ws.worker.kill(join_timeout=self.join_timeout)

    # -- shutdown ---------------------------------------------------------------------
    def shutdown(self) -> None:
        if self._listener is not None:
            self._listener.close()
            self._listener = None
        self._pump_shutdown.set()
        if self.transport_kind == "virtual":
            self._inbox.put(Ellipsis)
            self.clock.kick(self._inbox)
        super().shutdown()
        if self._host_spill_root is not None:
            import shutil
            shutil.rmtree(self._host_spill_root, ignore_errors=True)
            self._host_spill_root = None
