"""Socket-tier workers: real processes dialing the controller back over TCP.

The child runs the *unchanged* ``core.workers._child_main`` command loop — the
only cluster-specific code is the dial-in: connect to the controller's
listener, complete the magic/version/hello handshake, start a heartbeat
thread, then hand the framed transport to the command loop.

Parent side, ``ClusterListener`` owns the accept loop: it completes the
server handshake, checks the roster token, and hands the attached transport
to the executor keyed by trial_id.  Because the handshake carries the
trial_id, a worker that dials back after a broken connection re-attaches to
its *existing* handle instead of being treated as a stranger — the
reconnect-aware half of the framing contract.

The child's heartbeat cadence is real wall sleep (children are real processes
outside any VirtualClock); the *parent's* age math over those heartbeats rides
``clock.monotonic()`` only (DESIGN.md §7).
"""
from __future__ import annotations

import os
import socket as _socket
import threading
import time as _time
from typing import Any, Callable, Dict, Optional, Tuple

import multiprocessing as mp

from ..core.workers import TrainableFactory, _child_main, _default_context
from .transport import (SocketTransport, TransportError, client_handshake,
                        server_handshake)

__all__ = ["SocketProcessWorker", "ClusterListener", "socket_child_main"]


def socket_child_main(address: Tuple[str, int], token: str,
                      spec: Dict[str, Any]) -> None:
    """Worker process entry for the socket tier (spawn-safe, module-level).

    Dial the controller (with retries — the listener may still be binding, or
    a transient refusal may need riding out), handshake, start the heartbeat
    thread, and serve the standard command loop over the framed transport.
    """
    tr: Optional[SocketTransport] = None
    retries = int(spec.get("connect_retries", 5))
    for attempt in range(retries):
        sock = None
        try:
            sock = _socket.create_connection(tuple(address), timeout=10.0)
            sock.setsockopt(_socket.IPPROTO_TCP, _socket.TCP_NODELAY, 1)
            tr = client_handshake(sock, {
                "trial_id": spec["trial_id"],
                "pid": os.getpid(),
                "token": token,
            })
            break
        except (OSError, TransportError):
            if sock is not None:
                try:
                    sock.close()
                except OSError:
                    pass
            _time.sleep(0.2 * (attempt + 1))
    if tr is None:
        return  # controller unreachable; parent's spawn watchdog reclaims us

    hb = float(spec.get("heartbeat_interval", 0.0) or 0.0)
    if hb > 0:

        def _beat() -> None:
            while True:
                _time.sleep(hb)
                try:
                    tr.send_heartbeat()
                except (TransportError, OSError):
                    return  # controller gone; main loop sees it too

        threading.Thread(target=_beat, name="repro-heartbeat",
                         daemon=True).start()
    _child_main(tr, spec)


class SocketProcessWorker:
    """Parent-side handle on one socket-tier worker process.

    Same surface as ``core.workers.ProcessWorker`` (send/kill/join/close/
    alive/pid/transport) so the executor and pump need no tier branches.  The
    difference: ``transport`` starts as None and is attached by the listener
    when the child dials back — commands before READY are impossible by
    protocol, and the pump simply skips handles that have no transport yet.

    The mp.Process handle is kept even though messaging rides the socket:
    SIGKILL reclamation of a wedged child must not depend on a live TCP
    connection.
    """

    def __init__(
        self,
        factory: TrainableFactory,
        trial_id: str,
        config: Dict[str, Any],
        spill_dir: str,
        address: Tuple[str, int],
        token: str,
        checkpoint_freq: int = 0,
        restore_key: Optional[str] = None,
        restore_iteration: int = 0,
        heartbeat_interval: float = 5.0,
        mp_context: Optional[str] = None,
        nice: int = 1,
        trace: bool = False,
    ):
        spec = {
            "factory": factory,
            "trial_id": trial_id,
            "config": config,
            "spill_dir": spill_dir,
            "checkpoint_freq": checkpoint_freq,
            "restore_key": restore_key,
            "restore_iteration": restore_iteration,
            "nice": nice,
            "trace": trace,
            "cas": True,  # cluster checkpoints are content-addressed
            "heartbeat_interval": heartbeat_interval,
        }
        ctx = mp.get_context(mp_context) if mp_context else _default_context()
        self.transport: Optional[SocketTransport] = None
        self._send_lock = threading.Lock()
        self.process = ctx.Process(
            target=socket_child_main, args=(tuple(address), token, spec),
            name=f"repro-cluster-worker-{trial_id}", daemon=True)
        self.process.start()

    def attach(self, transport: SocketTransport) -> None:
        with self._send_lock:
            old, self.transport = self.transport, transport
        if old is not None:  # reconnect: the stale stream is dead
            old.close()

    @property
    def pid(self) -> Optional[int]:
        return self.process.pid

    def alive(self) -> bool:
        return self.process.is_alive()

    def send(self, *msg: Any) -> bool:
        try:
            with self._send_lock:
                if self.transport is None:
                    return False
                self.transport.send(msg)
            return True
        except (TransportError, OSError, ValueError, EOFError):
            return False

    def join(self, timeout: Optional[float] = None) -> bool:
        self.process.join(timeout=timeout)
        return not self.process.is_alive()

    def kill(self, join_timeout: float = 5.0) -> None:
        try:
            self.process.kill()
        except (OSError, AttributeError, ValueError):
            pass
        self.process.join(timeout=join_timeout)
        self.close()

    def close(self) -> None:
        if self.transport is not None:
            self.transport.close()


class ClusterListener:
    """The controller's single accept loop for every socket worker.

    One listening socket (loopback by default — real multi-host deployments
    would bind an interface), one daemon thread: each accepted connection is
    handshaken, token-checked, and delivered to ``on_attach(trial_id,
    transport, hello)``.  A handshake or token failure closes that connection
    and nothing else — a garbage-spewing dialer cannot take the listener down.
    """

    def __init__(self, on_attach: Callable[[str, SocketTransport, dict], None],
                 token: str, clock: Optional[Any] = None,
                 host: str = "127.0.0.1", max_frame: Optional[int] = None):
        self.on_attach = on_attach
        self.token = token
        self.clock = clock
        self._max_frame = max_frame
        self.sock = _socket.create_server((host, 0))
        self.sock.settimeout(0.2)
        self.address: Tuple[str, int] = self.sock.getsockname()[:2]
        self._stop = threading.Event()
        self.n_rejected = 0
        self.thread = threading.Thread(
            target=self._accept_loop, name="repro-cluster-accept", daemon=True)
        self.thread.start()

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, _addr = self.sock.accept()
            except _socket.timeout:
                continue
            except OSError:
                return  # listener closed
            try:
                sock.setsockopt(_socket.IPPROTO_TCP, _socket.TCP_NODELAY, 1)
                kwargs = {}
                if self._max_frame is not None:
                    kwargs["max_frame"] = self._max_frame
                tr, hello = server_handshake(sock, clock=self.clock, **kwargs)
            except (TransportError, OSError):
                self.n_rejected += 1
                try:
                    sock.close()
                except OSError:
                    pass
                continue
            if hello.get("token") != self.token:
                self.n_rejected += 1
                tr.close()
                continue
            try:
                self.on_attach(str(hello["trial_id"]), tr, hello)
            except Exception:  # noqa: BLE001 — never kill the accept loop
                tr.close()

    def close(self) -> None:
        self._stop.set()
        try:
            self.sock.close()
        except OSError:
            pass
        self.thread.join(timeout=2.0)
