"""Wire transports for the cluster tier (DESIGN.md §11).

The worker protocol (``repro.core.workers``) speaks over any *Transport*: an
object with ``send(obj)`` / ``recv() -> obj`` / ``poll(timeout) -> bool`` /
``close()``.  A duplex multiprocessing Connection already is one; this module
adds the two the cluster tier needs:

- ``SocketTransport``: length-prefixed pickle frames over a stream socket,
  with a magic/version handshake, zero-length heartbeat frames, and a hard
  frame-size cap so a corrupt length prefix fails loudly instead of allocating
  gigabytes.  The parent-side executor pump multiplexes sockets and pipe
  Connections through one ``multiprocessing.connection.wait`` call (both are
  selectable), so a mixed roster needs no second pump.
- ``VirtualTransport``: an in-memory endpoint pair whose blocking ``recv``
  parks through an injected clock, so ``repro.testing`` can script host
  crashes and network partitions deterministically under VirtualClock before
  any real socket is trusted.

Error taxonomy (deliberate MRO — the core pump/child loops catch
``(EOFError, OSError)`` and need no cluster imports):

- ``TransportClosed``  subclasses EOFError: the peer is gone (clean close,
  reset, or mid-frame disconnect).  Same recovery as a pipe EOF.
- ``FramingError``     subclasses OSError: the *bytes* are wrong (bad magic,
  oversized/corrupt length prefix, undecodable payload).  The peer may still
  be alive but the stream is unrecoverable — the cluster executor escalates
  this to host eviction rather than a single-worker death.

Heartbeat/reconnect age arithmetic rides ``clock.monotonic()`` exclusively
(never ``time.time()``): an NTP step on either end must not age a healthy
host into eviction, matching the wall-jump-safe contract of DESIGN.md §7.
"""
from __future__ import annotations

import pickle
import queue
import select
import socket as _socket
import struct
import threading
import time as _time
from typing import Any, Callable, Optional, Tuple

__all__ = [
    "TransportError", "TransportClosed", "FramingError",
    "MAGIC", "PROTO_VERSION", "DEFAULT_MAX_FRAME", "HEARTBEAT",
    "SocketTransport", "client_handshake", "server_handshake",
    "VirtualTransport", "virtual_pair",
]

MAGIC = b"RMSH"          # repro mesh
PROTO_VERSION = 1
DEFAULT_MAX_FRAME = 64 << 20   # 64 MiB: > any checkpoint key message, << RAM
_LEN = struct.Struct("!I")

#: Sentinel message a transport yields for a zero-length (heartbeat) frame.
#: It reaches ``_handle_message`` like any other child message; only the
#: cluster executor expects it (pipe children never send heartbeats).
HEARTBEAT: Tuple[str] = ("HEARTBEAT",)


class TransportError(Exception):
    """Base for transport failures."""


class TransportClosed(TransportError, EOFError):
    """Peer closed (cleanly or not).  EOFError-compatible on purpose."""


class FramingError(TransportError, OSError):
    """The byte stream is corrupt; the connection cannot be resynchronized.
    OSError-compatible so transport-agnostic loops treat it as fatal I/O."""


def _mono(clock: Optional[Any]) -> float:
    return clock.monotonic() if clock is not None else _time.monotonic()


class SocketTransport:
    """Length-prefixed pickle frames over a connected stream socket.

    ``send`` is locked (pump kicks vs runner lifecycle commands);  ``recv``
    has a single reader (the pump or the child loop) by protocol.  A
    zero-length frame is a heartbeat: it stamps ``last_recv_mono`` and is
    surfaced as the ``HEARTBEAT`` sentinel message.
    """

    def __init__(self, sock: _socket.socket, clock: Optional[Any] = None,
                 max_frame: int = DEFAULT_MAX_FRAME, name: str = ""):
        self.sock = sock
        self.name = name
        self.max_frame = int(max_frame)
        self._clock = clock
        self._send_lock = threading.Lock()
        self._closed = False
        #: monotonic instant of the last bytes seen from the peer — the ONLY
        #: input to heartbeat/eviction age math (wall time can step).
        self.last_recv_mono = _mono(clock)

    # -- Transport surface -------------------------------------------------------------
    @property
    def waitable(self) -> _socket.socket:
        """What the executor pump hands to ``multiprocessing.connection.wait``."""
        return self.sock

    def fileno(self) -> int:
        return self.sock.fileno()

    def send(self, obj: Any) -> None:
        payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        if len(payload) > self.max_frame:
            raise FramingError(
                f"outgoing frame of {len(payload)} bytes exceeds the "
                f"{self.max_frame}-byte cap")
        self._send_frame(payload)

    def send_heartbeat(self) -> None:
        """Zero-length liveness frame (child -> parent only)."""
        self._send_frame(b"")

    def _send_frame(self, payload: bytes) -> None:
        with self._send_lock:
            if self._closed:
                raise TransportClosed(f"transport {self.name or '?'} is closed")
            try:
                self.sock.sendall(_LEN.pack(len(payload)) + payload)
            except OSError as e:
                self._closed = True
                raise TransportClosed(f"peer gone during send: {e}") from e

    def recv(self) -> Any:
        hdr = self._read_exact(_LEN.size)
        (length,) = _LEN.unpack(hdr)
        self.last_recv_mono = _mono(self._clock)
        if length == 0:
            return HEARTBEAT
        if length > self.max_frame:
            # A corrupt length prefix looks like a multi-GiB frame; failing
            # here (before any allocation) is what keeps a garbage-spewing
            # peer from wedging or OOMing the pump.
            raise FramingError(
                f"incoming frame claims {length} bytes "
                f"(cap {self.max_frame}); stream is corrupt")
        payload = self._read_exact(length)
        try:
            return pickle.loads(payload)
        except Exception as e:  # noqa: BLE001 — anything unpicklable is framing
            raise FramingError(f"undecodable frame payload: {e!r}") from e

    def _read_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            try:
                chunk = self.sock.recv(n - len(buf))
            except OSError as e:
                self._closed = True
                raise TransportClosed(f"recv failed: {e}") from e
            if not chunk:
                self._closed = True
                if buf:
                    raise TransportClosed(
                        f"peer closed mid-frame ({len(buf)}/{n} bytes)")
                raise TransportClosed("peer closed")
            buf += chunk
        return bytes(buf)

    def poll(self, timeout: float = 0.0) -> bool:
        if self._closed:
            return True  # recv will raise TransportClosed promptly
        try:
            r, _, _ = select.select([self.sock], [], [], max(0.0, timeout or 0.0))
        except (OSError, ValueError):
            return True
        return bool(r)

    def close(self) -> None:
        self._closed = True
        try:
            self.sock.shutdown(_socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


# -- handshake ------------------------------------------------------------------------
# 5 raw bytes (magic + version) before any frame: a stray connection speaking
# the wrong protocol is rejected without ever being unpickled.  Then one hello
# frame identifies the worker ({"trial_id", "pid", "token"}), which is what
# makes reconnects possible: an acceptor can re-attach a dialing-back worker
# to its existing handle by trial_id instead of treating it as a stranger.

def client_handshake(sock: _socket.socket, hello: dict,
                     timeout: float = 10.0,
                     max_frame: int = DEFAULT_MAX_FRAME) -> SocketTransport:
    """Worker side: send magic+version, then the hello frame; await the ack."""
    sock.settimeout(timeout)
    tr = SocketTransport(sock, max_frame=max_frame,
                         name=str(hello.get("trial_id", "?")))
    try:
        sock.sendall(MAGIC + bytes([PROTO_VERSION]))
        tr.send(dict(hello))
        ack = tr.recv()
        if not (isinstance(ack, dict) and ack.get("ok")):
            raise FramingError(f"handshake rejected: {ack!r}")
    except _socket.timeout as e:
        raise TransportClosed("handshake timed out") from e
    sock.settimeout(None)
    return tr


def server_handshake(sock: _socket.socket, clock: Optional[Any] = None,
                     timeout: float = 10.0,
                     max_frame: int = DEFAULT_MAX_FRAME
                     ) -> Tuple[SocketTransport, dict]:
    """Acceptor side: verify magic+version, read the hello, ack.  Returns the
    framed transport and the hello dict identifying the worker."""
    sock.settimeout(timeout)
    try:
        head = b""
        while len(head) < len(MAGIC) + 1:
            chunk = sock.recv(len(MAGIC) + 1 - len(head))
            if not chunk:
                raise TransportClosed("peer closed during handshake")
            head += chunk
    except _socket.timeout as e:
        raise TransportClosed("handshake timed out") from e
    if head[:len(MAGIC)] != MAGIC:
        raise FramingError(f"bad magic {head[:len(MAGIC)]!r}")
    if head[len(MAGIC)] != PROTO_VERSION:
        raise FramingError(f"protocol version {head[len(MAGIC)]} != {PROTO_VERSION}")
    tr = SocketTransport(sock, clock=clock, max_frame=max_frame)
    hello = tr.recv()
    if not (isinstance(hello, dict) and hello.get("trial_id")):
        raise FramingError(f"malformed hello: {hello!r}")
    tr.name = str(hello["trial_id"])
    tr.send({"ok": True, "proto": PROTO_VERSION})
    sock.settimeout(None)
    return tr, hello


# -- virtual transport ----------------------------------------------------------------

_CLOSED = object()  # in-band EOF marker on a virtual endpoint's inbox


class VirtualTransport:
    """One endpoint of an in-memory duplex link under an injected clock.

    Blocking ``recv`` parks through ``clock.queue_get`` so a VirtualClock can
    advance around it; producers kick the consumer's queue channel.  The link
    owns an optional ``drop(sender_endpoint, obj) -> bool`` filter: returning
    True silently swallows the frame — that is a network partition, which
    (like a real one) produces *no* EOF; only heartbeat age can detect it.
    A filter that wants TCP semantics (a blip delays, retransmission delivers
    after the heal) can stash ``(sender_endpoint, obj)`` and replay later via
    ``deliver``, which bypasses the filter.  ``close`` is a process death:
    the peer sees EOF (``TransportClosed``).
    """

    def __init__(self, clock: Any, side: str, name: str = ""):
        self.clock = clock
        self.side = side
        self.name = name
        self._q: "queue.Queue" = queue.Queue()
        self.peer: Optional["VirtualTransport"] = None
        self.closed = False
        self.drop: Optional[Callable[[str, Any], bool]] = None
        self.on_deliver: Optional[Callable[[], None]] = None
        self.last_recv_mono = clock.monotonic()

    # The virtual pump is driven by on_deliver notifications, not select():
    # there is no OS object to wait on.
    waitable = None

    def send(self, obj: Any) -> None:
        peer = self.peer
        if self.closed or peer is None:
            raise TransportClosed(f"virtual endpoint {self.name} is closed")
        if peer.closed:
            raise TransportClosed(f"peer of {self.name} is closed")
        if self.drop is not None and self.drop(self, obj):
            return  # partitioned: the frame vanishes, no error, no EOF
        self.deliver(obj)

    def deliver(self, obj: Any) -> bool:
        """Put ``obj`` on the peer's inbox, bypassing the drop filter — the
        retransmission path a partition heal replays through.  Returns False
        (frame lost for good) if either end has since closed."""
        peer = self.peer
        if peer is None or peer.closed or self.closed:
            return False
        peer._q.put(obj)
        self.clock.kick(peer._q)
        if peer.on_deliver is not None:
            peer.on_deliver()
        return True

    def send_heartbeat(self) -> None:
        self.send(HEARTBEAT)

    def recv(self) -> Any:
        while True:
            if self.closed and self._q.empty():
                raise TransportClosed(f"virtual endpoint {self.name} is closed")
            got = self.clock.queue_get(self._q, timeout=3600.0)
            if got is None:
                continue  # spurious/virtual timeout: park again
            if got is _CLOSED:
                self.closed = True
                raise TransportClosed(f"peer of {self.name} closed")
            self.last_recv_mono = self.clock.monotonic()
            return got

    def poll(self, timeout: float = 0.0) -> bool:
        if not self._q.empty() or self.closed:
            return True
        if timeout and timeout > 0:
            return self.clock.wait_for(
                lambda: not self._q.empty() or self.closed,
                timeout, channel=self._q)
        return False

    def close(self) -> None:
        """Drop this endpoint; the peer observes EOF (like a process exit).
        Bypasses the partition filter on purpose: a SIGKILL'd process's FIN
        still reaches a reachable peer."""
        if self.closed:
            return
        self.closed = True
        peer = self.peer
        if peer is not None and not peer.closed:
            peer._q.put(_CLOSED)
            self.clock.kick(peer._q)
            if peer.on_deliver is not None:
                peer.on_deliver()
        # Wake our own parked reader too (the child loop blocking in recv).
        self._q.put(_CLOSED)
        self.clock.kick(self._q)


def virtual_pair(clock: Any, name: str = "",
                 drop: Optional[Callable[[str, Any], bool]] = None,
                 on_deliver_parent: Optional[Callable[[], None]] = None
                 ) -> Tuple[VirtualTransport, VirtualTransport]:
    """A connected (parent_end, child_end) VirtualTransport pair.

    ``drop`` filters frames in BOTH directions (sender side is passed);
    ``on_deliver_parent`` fires after a frame lands in the parent's inbox —
    the cluster executor uses it to nudge its virtual pump."""
    parent = VirtualTransport(clock, "parent", name=f"{name}/parent")
    child = VirtualTransport(clock, "child", name=f"{name}/child")
    parent.peer, child.peer = child, parent
    parent.drop = child.drop = drop
    parent.on_deliver = on_deliver_parent
    return parent, child
