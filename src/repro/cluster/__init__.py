"""repro.cluster — the multi-host tier (DESIGN.md §11).

Layering: ``repro.core`` never imports this package.  The worker command loop
(``core.workers._child_main``) and the executor pump are transport-agnostic by
duck typing — anything with ``send/recv/poll/close`` works — and this package
supplies the non-pipe transports plus the host-roster executor that schedules
trials across per-host SlicePools.

Public surface:

- ``Transport`` errors + ``SocketTransport`` / ``VirtualTransport`` framing
  (``repro.cluster.transport``)
- ``HostSpec`` / ``HostAgent`` / ``parse_hosts`` roster (``repro.cluster.hosts``)
- ``FixedPlacement`` / ``RooflinePlacement`` (``repro.cluster.placement``)
- ``ClusterMeshExecutor`` (``repro.cluster.executor``)
- ``SimFleet`` scripted host faults under VirtualClock (``repro.cluster.sim``)
"""
from .transport import (FramingError, SocketTransport, TransportClosed,
                        TransportError, VirtualTransport, virtual_pair)
from .hosts import HostAgent, HostSpec, parse_hosts
from .placement import FixedPlacement, RooflinePlacement
from .executor import ClusterMeshExecutor

__all__ = [
    "TransportError", "TransportClosed", "FramingError",
    "SocketTransport", "VirtualTransport", "virtual_pair",
    "HostSpec", "HostAgent", "parse_hosts",
    "FixedPlacement", "RooflinePlacement",
    "ClusterMeshExecutor",
]
