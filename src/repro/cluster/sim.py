"""Simulated host fleet under VirtualClock (DESIGN.md §11).

Before a single real socket is trusted, every cluster failure mode must be
rehearsable deterministically: ``VirtualWorker`` runs the *unchanged* worker
command loop (``core.workers._child_main``) in a clock-registered thread over
a ``VirtualTransport`` pair, and ``SimFleet`` scripts host faults on the
virtual timeline:

- **crash**: the host goes dark instantly — every worker link drops with EOF
  (``ClusterMeshExecutor.fail_host``), the pump errors each resident trial,
  max_failures restarts them elsewhere.
- **partition**: frames in BOTH directions silently stall (no EOF — exactly
  like a real partition) and the host's heartbeat touches stop; nothing
  detects it except monotonic heartbeat age, which escalates to host
  eviction at ``host_timeout``.  A heal *before* the timeout replays the
  buffered frames in order (TCP retransmission over a surviving
  connection), so a short blip costs latency, not work.

The fleet's heartbeat thread stands in for per-host agent daemons: it touches
every alive, un-partitioned host on a cadence, so a healthy-but-idle host
never ages into eviction while a partitioned one does.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, Dict, List, Optional, Set, Tuple

from ..core.workers import TrainableFactory, _child_main
from .transport import virtual_pair

__all__ = ["SimNetwork", "SimFleet", "VirtualWorker"]


class _FakeProcess:
    """Just enough of the mp.Process surface for the executor's death path
    (``exitcode`` in ERROR events, ``pid`` in KILLED events)."""

    def __init__(self, pid: int):
        self.pid = pid
        self.exitcode: Optional[int] = None


class SimNetwork:
    """Partition state shared by every virtual link in the fleet.

    While a host is partitioned its frames vanish *silently* in both
    directions — the defining property of a partition is that neither side
    learns anything.  The frames are buffered, not destroyed: on ``heal``
    they are replayed in send order, which is what surviving TCP connections
    do after a blip (retransmission).  A host evicted *during* the partition
    never gets its backlog — its links were closed with the eviction, and
    ``deliver`` drops frames for closed endpoints on the floor.
    """

    def __init__(self) -> None:
        self._partitioned: Set[str] = set()
        self._lock = threading.Lock()
        self._buffered: Dict[str, List[Tuple[Any, Any]]] = {}
        self.n_dropped = 0
        self.n_replayed = 0

    def partition(self, host: str) -> None:
        with self._lock:
            self._partitioned.add(host)

    def heal(self, host: str) -> None:
        with self._lock:
            self._partitioned.discard(host)
            backlog = self._buffered.pop(host, [])
        for endpoint, obj in backlog:
            if endpoint.deliver(obj):
                with self._lock:
                    self.n_replayed += 1

    def is_partitioned(self, host: str) -> bool:
        with self._lock:
            return host in self._partitioned

    def drop_filter(self, host: str):
        def _drop(endpoint: Any, obj: Any) -> bool:
            with self._lock:
                if host not in self._partitioned:
                    return False
                self.n_dropped += 1
                self._buffered.setdefault(host, []).append((endpoint, obj))
            return True
        return _drop


class VirtualWorker:
    """In-process stand-in for a worker process: the real ``_child_main``
    loop in a clock-registered thread over a virtual link.

    Mirrors the ``ProcessWorker`` surface the executor relies on
    (``transport`` / ``send`` / ``kill`` / ``join`` / ``close`` / ``alive`` /
    ``pid`` / ``process.exitcode``) plus ``die()`` — the *crash* primitive:
    the link drops with EOF but nothing is marked as a deliberate kill, so
    the pump takes the same unexpected-death path a SIGKILL'd real child
    triggers."""

    _pids = itertools.count(100000)

    def __init__(self, clock: Any, factory: TrainableFactory, trial_id: str,
                 config: Dict[str, Any], spill_dir: str,
                 checkpoint_freq: int = 0, restore_key: Optional[str] = None,
                 restore_iteration: int = 0, trace: bool = False,
                 network: Optional[SimNetwork] = None,
                 host: Optional[str] = None, inbox_notify: Any = None):
        self.clock = clock
        self.process = _FakeProcess(next(self._pids))
        drop = network.drop_filter(host) if network is not None and host else None
        self.transport, child_tr = virtual_pair(
            clock, name=trial_id, drop=drop, on_deliver_parent=inbox_notify)
        self._child_tr = child_tr
        self._send_lock = threading.Lock()
        spec = {
            "factory": factory,
            "trial_id": trial_id,
            "config": config,
            "spill_dir": spill_dir,
            "checkpoint_freq": checkpoint_freq,
            "restore_key": restore_key,
            "restore_iteration": restore_iteration,
            "nice": 0,
            "trace": trace,
            "cas": True,
        }
        self._thread = threading.Thread(
            target=self._run, args=(child_tr, spec),
            name=f"repro-vworker-{trial_id}", daemon=True)
        self._thread.start()

    def _run(self, transport: Any, spec: Dict[str, Any]) -> None:
        with self.clock.running():
            try:
                _child_main(transport, spec)
            finally:
                if self.process.exitcode is None:
                    self.process.exitcode = 0

    # -- ProcessWorker surface ---------------------------------------------------------
    @property
    def pid(self) -> int:
        return self.process.pid

    def alive(self) -> bool:
        return self._thread.is_alive()

    def send(self, *msg: Any) -> bool:
        try:
            with self._send_lock:
                self.transport.send(msg)
            return True
        except (EOFError, OSError, ValueError):
            return False

    def join(self, timeout: Optional[float] = None) -> bool:
        return self.clock.join_thread(self._thread, timeout=timeout)

    def kill(self, join_timeout: float = 5.0) -> None:
        """Deliberate teardown (evictions, reap escalation): drop the link
        (the child's recv raises EOF and the loop exits) and settle the
        thread."""
        if self.process.exitcode is None:
            self.process.exitcode = -9
        self.transport.close()
        self.clock.join_thread(self._thread, timeout=join_timeout)

    def die(self) -> None:
        """Scripted crash: the CHILD side vanishes — the parent endpoint sees
        EOF exactly as if the process had been SIGKILL'd externally."""
        if self.process.exitcode is None:
            self.process.exitcode = -9
        self._child_tr.close()

    def close(self) -> None:
        self.transport.close()


class SimFleet:
    """Scripted fault driver + host heartbeats on the virtual timeline.

    Usage::

        fleet = SimFleet(executor, clock)
        fleet.script("crash", "h1", at=30.0)
        fleet.script("partition", "h2", at=50.0, duration=40.0)
        executor.sim = fleet   # workers spawned from here on join the network
        fleet.start()
        ... run the experiment ...
        fleet.stop()

    Both threads (heartbeat + fault driver) park through the injected clock,
    so two identical-token runs replay the same fault sequence at the same
    virtual instants.  Times are ``clock.monotonic()`` offsets from start().
    """

    def __init__(self, executor: Any, clock: Any,
                 heartbeat_interval: float = 5.0):
        self.executor = executor
        self.clock = clock
        self.network = SimNetwork()
        self.heartbeat_interval = float(heartbeat_interval)
        self._events: List[Tuple[float, str, str]] = []
        self._stop = clock.event()
        self._threads: List[threading.Thread] = []
        self.n_faults_fired = 0

    def script(self, kind: str, host: str, at: float,
               duration: Optional[float] = None) -> None:
        if kind not in ("crash", "partition", "heal"):
            raise ValueError(f"unknown fault kind {kind!r}")
        self._events.append((float(at), kind, host))
        if kind == "partition" and duration is not None:
            self._events.append((float(at) + float(duration), "heal", host))

    def start(self) -> None:
        self.executor.sim = self
        for target, name in ((self._heartbeat_loop, "repro-sim-heartbeat"),
                             (self._fault_loop, "repro-sim-faults")):
            t = threading.Thread(target=target, name=name, daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        self.clock.kick()
        for t in self._threads:
            self.clock.join_thread(t, timeout=5.0)
        self._threads.clear()

    # -- loops (clock-registered) ------------------------------------------------------
    def _heartbeat_loop(self) -> None:
        with self.clock.running():
            while not self._stop.wait(self.heartbeat_interval):
                for name, host in list(self.executor.hosts.items()):
                    if host.alive and not self.network.is_partitioned(name):
                        self.executor.touch_host(name)

    def _fault_loop(self) -> None:
        with self.clock.running():
            t0 = self.clock.monotonic()
            for at, kind, host in sorted(self._events):
                while True:
                    remaining = (t0 + at) - self.clock.monotonic()
                    if remaining <= 0:
                        break
                    if self._stop.wait(remaining):
                        return
                if self._stop.is_set():
                    return
                if kind == "crash":
                    self.executor.fail_host(host, reason="scripted host crash")
                elif kind == "partition":
                    self.network.partition(host)
                elif kind == "heal":
                    self.network.heal(host)
                self.n_faults_fired += 1
