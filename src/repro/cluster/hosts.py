"""Host roster for the cluster tier (DESIGN.md §11).

A *host* is one failure domain: its own device pool (SlicePool), its own
checkpoint spill surface (ObjectStore with a private spill dir — simulating a
separate filesystem), and its own liveness state.  The controller schedules
trials *onto* hosts; when a host dies, every trial on it fails together and
each restart is charged to that trial's ``max_failures`` budget.

Checkpoint bytes cross hosts with ``fetch``: a content-addressed copy over the
ObjectStore spill surface.  ``cas/<trial>/<sha256>`` keys carry their own
digest, so the destination re-hashes after the copy and a torn or corrupted
spill file fails the fetch instead of silently restoring garbage.

This module is jax-free: host hardware is described by throughput constants
(the same axes as ``launch/roofline.py``'s ``HW``), not device handles.
"""
from __future__ import annotations

import hashlib
import os
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

from ..dist.submesh import SlicePool
from ..core.object_store import ObjectStore

__all__ = ["HostSpec", "HostAgent", "parse_hosts", "fetch"]


@dataclass(frozen=True)
class HostSpec:
    """Static description of one host: capacity + roofline throughputs.

    Defaults mirror ``launch.roofline.HW`` (a TPU-class device) so placement
    math is consistent between the in-host profiler and the cluster scheduler;
    heterogeneous rosters override per host.  Units: FLOP/s, bytes/s.
    """
    name: str
    devices: int = 8
    peak_flops: float = 1.0e15      # per-device BF16 peak
    hbm_bw: float = 1.2e12          # per-device HBM bytes/s
    link_bw: float = 1.0e11         # inter-device interconnect bytes/s


class HostAgent:
    """Live controller-side state for one host.

    ``last_seen`` is a ``clock.monotonic()`` instant — liveness age math must
    never touch wall time (an NTP step would age every host at once).
    """

    def __init__(self, spec: HostSpec, clock: Any,
                 spill_root: Optional[str] = None,
                 store_capacity: int = 1 << 20):
        self.spec = spec
        self.name = spec.name
        self.pool = SlicePool(n_virtual=spec.devices)
        spill_dir = None
        if spill_root is not None:
            spill_dir = os.path.join(spill_root, spec.name)
            os.makedirs(spill_dir, exist_ok=True)
        # Small in-memory window: host stores exist as spill surfaces, the
        # payloads live on "the host's disk".
        self.store = ObjectStore(capacity_bytes=store_capacity,
                                 spill_dir=spill_dir)
        if spill_dir is None:
            self.store.ensure_spill_dir()
        self.alive = True
        self.last_seen: float = clock.monotonic()
        self.trials: Set[str] = set()   # trials currently placed here
        self.n_evictions = 0
        self.evicted_reason: Optional[str] = None

    def touch(self, now: float) -> None:
        if now > self.last_seen:
            self.last_seen = now

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"HostAgent({self.name}, devices={self.spec.devices}, "
                f"alive={self.alive}, free={self.pool.n_free})")


_HOSTS_RE = re.compile(r"^(\d+)x(\d+)$")


def parse_hosts(hosts: Any) -> List[HostSpec]:
    """Coerce the ``hosts=`` argument into a HostSpec roster.

    Accepted forms:
      - int ``3``                      -> 3 hosts x 8 devices
      - str ``"3x8"``                  -> 3 hosts x 8 devices
      - str ``"h0:8,h1:4,h2:16"``      -> named hosts with device counts
      - list of HostSpec               -> passed through
      - list of (name, devices) pairs
    """
    if isinstance(hosts, int):
        return [HostSpec(name=f"h{i}") for i in range(hosts)]
    if isinstance(hosts, str):
        m = _HOSTS_RE.match(hosts.strip())
        if m:
            n, dev = int(m.group(1)), int(m.group(2))
            return [HostSpec(name=f"h{i}", devices=dev) for i in range(n)]
        specs = []
        for part in hosts.split(","):
            part = part.strip()
            if not part:
                continue
            if ":" in part:
                name, dev = part.split(":", 1)
                specs.append(HostSpec(name=name.strip(), devices=int(dev)))
            else:
                specs.append(HostSpec(name=part))
        if not specs:
            raise ValueError(f"unparseable hosts spec {hosts!r}")
        hosts = specs  # fall through to shared roster validation
    out = []
    for h in hosts:
        if isinstance(h, HostSpec):
            out.append(h)
        else:
            name, dev = h
            out.append(HostSpec(name=str(name), devices=int(dev)))
    if not out:
        raise ValueError("empty host roster")
    names = [h.name for h in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate host names in roster: {names}")
    return out


_CAS_RE = re.compile(r"^cas/[^/]+/([0-9a-f]{64})$")


def fetch(key: str, src: ObjectStore, dst: ObjectStore) -> str:
    """Copy ``key``'s payload from one host's store to another's.

    The transfer rides the spill surface (bytes on disk), peeked from the
    source so the copy does not disturb its LRU.  For content-addressed
    (``cas/``) keys the payload is re-hashed and must match the digest baked
    into the key — the cross-host integrity check.  Returns the key.
    """
    payload = src.peek(key)  # KeyError if the host never wrote it
    if not isinstance(payload, (bytes, bytearray)):
        raise TypeError(
            f"fetch: {key!r} holds a live object, not spillable bytes")
    payload = bytes(payload)
    m = _CAS_RE.match(key)
    if m is not None:
        digest = hashlib.sha256(payload).hexdigest()
        if digest != m.group(1):
            raise IOError(
                f"fetch: content digest mismatch for {key!r} "
                f"(got {digest[:12]}..., torn or corrupt spill file)")
    dst.put_spilled(payload, key=key)
    return key
