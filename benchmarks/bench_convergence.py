"""Scheduler-quality benchmark: best loss found vs total iteration budget.

Paper claim: intermediate-result schedulers (ASHA/HyperBand/Median/PBT) find
comparable optima at a fraction of FIFO's budget, and TPE beats random
sampling — all through the same interface.  Surrogate objective (common.py)
keeps this CPU-cheap; the tune launcher runs the same comparison on real
models.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (ASHAScheduler, CheckpointManager, FIFOScheduler,
                        GPSearcher, HyperBandScheduler, MedianStoppingRule,
                        ObjectStore, PopulationBasedTraining, TPESearcher,
                        RandomSearcher, SerialMeshExecutor, Trial, TrialRunner,
                        loguniform)

from .common import SurrogateTrainable, emit, write_csv

MAX_T = 30
N_TRIALS = 24
SPACE = {"lr": loguniform(1e-4, 1e0)}


def _make_scheduler(name: str):
    if name == "fifo":
        return FIFOScheduler(metric="loss", mode="min")
    if name == "asha":
        return ASHAScheduler(metric="loss", mode="min", max_t=MAX_T,
                             grace_period=3, reduction_factor=3)
    if name == "hyperband":
        return HyperBandScheduler(metric="loss", mode="min", max_t=27, eta=3)
    if name == "median":
        return MedianStoppingRule(metric="loss", mode="min", grace_period=3)
    if name == "pbt":
        return PopulationBasedTraining(
            metric="loss", mode="min", perturbation_interval=5,
            hyperparam_mutations={"lr": loguniform(1e-4, 1e0)}, seed=0)
    raise ValueError(name)


def run_one(name: str, seed: int) -> Dict:
    rng = np.random.default_rng(seed)
    searcher = None
    n_sugg = N_TRIALS + 8
    if name == "tpe":
        searcher = TPESearcher(SPACE, metric="loss", mode="min",
                               n_startup_trials=6, max_trials=n_sugg, seed=seed)
    elif name == "random":
        searcher = RandomSearcher(SPACE, metric="loss", mode="min",
                                  max_trials=n_sugg, seed=seed)
    elif name == "gp":
        searcher = GPSearcher(SPACE, metric="loss", mode="min",
                              n_startup_trials=6, max_trials=n_sugg, seed=seed)
    # searchers run narrower (4-wide) so suggestions see more feedback
    executor = SerialMeshExecutor(lambda n: SurrogateTrainable,
                                  CheckpointManager(ObjectStore()),
                                  total_devices=4 if searcher else 8,
                                  checkpoint_freq=1)
    sched = _make_scheduler(name) if searcher is None else FIFOScheduler(
        metric="loss", mode="min")
    runner = TrialRunner(sched, executor, searcher=searcher,
                         stopping_criteria={"training_iteration": MAX_T})
    if searcher is None:
        for i in range(N_TRIALS):
            lr = float(10 ** rng.uniform(-4, 0))
            runner.add_trial(Trial({"lr": lr, "seed": seed * 1000 + i},
                                   stopping_criteria={"training_iteration": MAX_T}))
    t0 = time.time()
    trials = runner.run()
    wall = time.time() - t0
    best = min(t.best_value("loss", "min") for t in trials
               if t.best_value("loss", "min") is not None)
    budget = sum(t.training_iteration for t in trials)
    # exploitation quality: mean best-loss of the LAST 8 launched trials —
    # separates informed searchers (TPE) from uninformed ones even when the
    # objective floor compresses the single-best numbers.
    late = [t.best_value("loss", "min") for t in trials[-8:]
            if t.best_value("loss", "min") is not None]
    return {"scheduler": name, "seed": seed, "best_loss": round(best, 4),
            "late_mean_loss": round(float(np.mean(late)), 4) if late else None,
            "total_iters": budget, "full_budget": N_TRIALS * MAX_T,
            "budget_frac": round(budget / (N_TRIALS * MAX_T), 3),
            "wall_s": round(wall, 2)}


def run() -> List[Dict]:
    rows = []
    for name in ("fifo", "random", "tpe", "gp", "asha", "hyperband", "median", "pbt"):
        per_seed = [run_one(name, s) for s in range(3)]
        best = float(np.mean([r["best_loss"] for r in per_seed]))
        frac = float(np.mean([r["budget_frac"] for r in per_seed]))
        late = float(np.mean([r["late_mean_loss"] for r in per_seed
                              if r["late_mean_loss"] is not None]))
        rows.extend(per_seed)
        emit(f"convergence/{name}",
             float(np.mean([r["wall_s"] for r in per_seed])) * 1e6,
             f"best={best:.4f} late_mean={late:.4f} budget_frac={frac:.2f}")
    write_csv("convergence", rows)
    return rows
