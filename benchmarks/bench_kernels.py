"""Kernel micro-bench: wall time of the pure-jnp oracle paths on CPU.

On this container the Pallas kernels run in interpret mode (Python-speed, not
meaningful to time); the oracle timings give the jnp baseline that a real-TPU
Mosaic build would be compared against, and regression-guard the reference
implementations.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.kernels import ref

from .common import emit, write_csv


def _time(fn, *args, reps=5) -> float:
    out = fn(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def run() -> List[Dict]:
    key = jax.random.key(0)
    rows = []

    B, S, H, K, hd = 2, 1024, 8, 2, 64
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, K, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    fa = jax.jit(lambda *a: ref.flash_attention_ref(*a, causal=True))
    t = _time(fa, q, k, v, pos, pos)
    flops = 4 * B * H * S * S * hd
    rows.append({"kernel": "attention_ref", "shape": f"B{B} S{S} H{H} hd{hd}",
                 "us_per_call": round(t * 1e6, 1),
                 "gflops_s": round(flops / t / 1e9, 1)})
    emit("kernels/attention_ref", t * 1e6, f"{flops/t/1e9:.0f} GFLOP/s cpu")

    B, S, H, N = 2, 512, 4, 64
    r = jax.random.normal(jax.random.fold_in(key, 4), (B, S, H, N)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(key, 5), (B, S, H, N)) * 0.5
    vv = jax.random.normal(jax.random.fold_in(key, 6), (B, S, H, N)) * 0.5
    logw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 7), (B, S, H, N)) - 2)
    u = jax.random.normal(jax.random.fold_in(key, 8), (H, N)) * 0.3
    s0 = jnp.zeros((B, H, N, N))
    rw = jax.jit(ref.rwkv6_scan_ref)
    t = _time(rw, r, kk, vv, logw, u, s0)
    rows.append({"kernel": "rwkv6_ref", "shape": f"B{B} S{S} H{H} N{N}",
                 "us_per_call": round(t * 1e6, 1), "gflops_s": ""})
    emit("kernels/rwkv6_ref", t * 1e6, f"S={S} sequential scan")

    B, S, R = 4, 2048, 512
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 9), (B, S, R)))
    b = jax.random.normal(jax.random.fold_in(key, 10), (B, S, R)) * 0.3
    rg = jax.jit(ref.rglru_scan_ref)
    t = _time(rg, a, b, None)
    rows.append({"kernel": "rglru_ref", "shape": f"B{B} S{S} R{R}",
                 "us_per_call": round(t * 1e6, 1), "gflops_s": ""})
    emit("kernels/rglru_ref", t * 1e6, f"{B*S*R*3/t/1e9:.1f} Gelem-op/s")

    T, E, topk = 8192, 64, 6
    logits = jax.random.normal(jax.random.fold_in(key, 11), (T, E)) * 2
    ro = jax.jit(lambda l: ref.moe_router_ref(l, topk))
    t = _time(ro, logits)
    rows.append({"kernel": "router_ref", "shape": f"T{T} E{E} k{topk}",
                 "us_per_call": round(t * 1e6, 1), "gflops_s": ""})
    emit("kernels/router_ref", t * 1e6, f"{T/t/1e6:.1f} Mtok/s")
    write_csv("kernels", rows)
    return rows
