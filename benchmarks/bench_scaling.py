"""Scaling benchmark: cluster occupancy under irregular trials.

Simulates the paper's §4.3.1 setting: trials request heterogeneous device
slices from the SlicePool while the FIFO scheduler launches whenever capacity
frees.  We measure achieved device-step occupancy vs an oracle upper bound,
and the fragmentation behaviour of first-fit placement.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (CheckpointManager, FIFOScheduler, ObjectStore,
                        Resources, SerialMeshExecutor, Trainable, Trial,
                        TrialRunner)
from repro.dist.submesh import SlicePool

try:
    from .common import emit, write_csv
except ImportError:  # direct run: python benchmarks/bench_scaling.py
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit, write_csv


class TimedTrainable(Trainable):
    def setup(self, config):
        self.n = 0
        self.length = config["length"]

    def step(self):
        self.n += 1
        return {"loss": 1.0 / self.n, "done": self.n >= self.length}

    def save(self):
        return {"n": self.n}

    def restore(self, s):
        self.n = s["n"]


class OccupancyProbe:
    """Wraps the executor's accountant to sample device occupancy per event."""

    def __init__(self, executor, total_devices):
        self.executor = executor
        self.total = total_devices
        self.samples: List[int] = []

    def sample(self):
        used = self.total - self.executor.accountant.available.devices
        self.samples.append(int(used))


def run_case(total_devices: int, sizes: List[int], lengths: List[int],
             seed: int) -> Dict:
    rng = np.random.default_rng(seed)
    pool = SlicePool(n_virtual=total_devices)
    executor = SerialMeshExecutor(lambda n: TimedTrainable,
                                  CheckpointManager(ObjectStore()),
                                  total_devices=total_devices,
                                  slice_pool=pool, checkpoint_freq=0)
    runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), executor)
    probe = OccupancyProbe(executor, total_devices)
    n_trials = 40
    trial_sizes = rng.choice(sizes, n_trials)
    trial_lens = rng.choice(lengths, n_trials)
    for sz, ln in zip(trial_sizes, trial_lens):
        runner.add_trial(Trial({"length": int(ln)},
                               resources=Resources(cpu=0, devices=int(sz))))
    # drive manually to sample occupancy per event
    while runner.step():
        probe.sample()
    device_steps = int(np.sum(trial_sizes * trial_lens))
    total_event_capacity = len(probe.samples) * total_devices
    occupancy = float(np.mean(probe.samples)) / total_devices
    return {
        "devices": total_devices,
        "sizes": "/".join(map(str, sizes)),
        "mean_occupancy": round(occupancy, 3),
        "events": len(probe.samples),
        "device_steps": device_steps,
        "fragmentation_stalls": 0 if pool.n_free == total_devices else 1,
    }


def run() -> List[Dict]:
    rows = []
    for devices, sizes in ((64, [8]), (64, [4, 8, 16]), (256, [8, 16, 32, 64])):
        t0 = time.time()
        row = run_case(devices, sizes, lengths=[5, 10, 20, 40], seed=0)
        rows.append(row)
        emit(f"scaling/dev{devices}_sizes{len(sizes)}",
             (time.time() - t0) * 1e6,
             f"occupancy={row['mean_occupancy']}")
    write_csv("scaling", rows)
    return rows


if __name__ == "__main__":
    run()
