"""Beyond-paper benchmark: serial per-trial stepping vs VmapExecutor.

Same workload (N trials of a tiny LM, identical schedules), two executors —
measures trial-steps/second.  The vmap path turns model selection into one
SPMD program; the serial path mirrors Ray Tune's actor-per-trial dispatch.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CheckpointManager, FIFOScheduler, ObjectStore,
                        SerialMeshExecutor, Trial, TrialRunner)
from repro.core.vmap_executor import VectorTrainableSpec, VmapExecutor
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import ModelConfig, forward_train, init_params
from repro.train.trainable import make_model_trainable

from .common import emit, write_csv

CFG = ModelConfig(arch_id="bench", family="dense", n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=256).validate()
BATCH, SEQ, ITERS = 4, 32, 6


def _serial(n_trials: int, lrs) -> float:
    cls = make_model_trainable(CFG, batch=BATCH, seq_len=SEQ, steps_per_iter=1,
                               total_steps=ITERS)
    executor = SerialMeshExecutor(lambda n: cls, CheckpointManager(ObjectStore()),
                                  total_devices=n_trials, checkpoint_freq=0)
    runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), executor,
                         trainable_name="bench",
                         stopping_criteria={"training_iteration": ITERS})
    from repro.core.experiment import register_trainable
    register_trainable("bench", cls)
    for lr in lrs:
        runner.add_trial(Trial({"lr": float(lr)}, trainable_name="bench",
                               stopping_criteria={"training_iteration": ITERS}))
    t0 = time.time()
    runner.run()
    return time.time() - t0


def _vmapped(n_trials: int, lrs) -> float:
    data = SyntheticLMDataset(DataConfig(global_batch=BATCH, seq_len=SEQ,
                                         vocab_size=CFG.vocab_size))
    batches = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[jax.tree_util.tree_map(jnp.asarray, data.batch_at(i))
                                     for i in range(8)])

    def init_fn(seed, hypers):
        params = init_params(jax.random.key(seed), CFG)
        mom = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
        return {"p": params, "m": mom, "i": jnp.zeros((), jnp.int32)}

    def step_fn(state, hypers):
        batch = jax.tree_util.tree_map(lambda x: x[state["i"] % 8], batches)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: forward_train(p, batch, CFG), has_aux=True)(state["p"])
        m = jax.tree_util.tree_map(lambda mo, g: 0.9 * mo + g, state["m"], grads)
        p = jax.tree_util.tree_map(lambda w, mo: w - hypers["lr"] * mo,
                                   state["p"], m)
        return {"p": p, "m": m, "i": state["i"] + 1}, {"loss": metrics["loss"]}

    spec = VectorTrainableSpec(init_fn, step_fn, ("lr",))
    ex = VmapExecutor(spec, CheckpointManager(ObjectStore()),
                      n_lanes=n_trials, checkpoint_freq=0)
    runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                         stopping_criteria={"training_iteration": ITERS})
    for lr in lrs:
        runner.add_trial(Trial({"lr": float(lr)},
                               stopping_criteria={"training_iteration": ITERS}))
    t0 = time.time()
    runner.run()
    return time.time() - t0


def run() -> List[Dict]:
    rows = []
    for n in (4, 8):
        lrs = np.logspace(-3, -1, n)
        t_serial = _serial(n, lrs)
        t_vmap = _vmapped(n, lrs)
        steps = n * ITERS
        rows.append({"n_trials": n,
                     "serial_steps_per_s": round(steps / t_serial, 2),
                     "vmap_steps_per_s": round(steps / t_vmap, 2),
                     "speedup": round(t_serial / t_vmap, 2)})
        emit(f"vmap/n{n}", t_vmap / steps * 1e6,
             f"speedup={t_serial/t_vmap:.2f}x vs serial")
    write_csv("vmap_executor", rows)
    return rows
