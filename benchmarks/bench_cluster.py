"""Cluster-tier benchmark: localhost 3-host socket sweep vs the process tier.

Same sweep, two control planes:

- **process** — workers over spawn pipes, one shared SlicePool (the in-host
  tier bench_process gates),
- **cluster** — ``ClusterMeshExecutor`` over the length-prefixed socket
  transport: 3 simulated hosts on loopback, per-host SlicePools, host
  heartbeats, content-addressed checkpoint fetch on every adoption.

The delta is the cluster control plane's whole bill — framing, the accept
loop, host bookkeeping, CAS hashing — measured in end-to-end wall and in
steady-state result throughput (boot amortized).  On loopback with
real-work steps the two tiers should be close; the CI smoke gates the
cluster tier at >= --min-ratio of the process tier's end-to-end throughput
so a framing or heartbeat regression that taxes every result shows up as a
red build.

    python benchmarks/bench_cluster.py --trials 6 --iters 20 --step-ms 20
    python benchmarks/bench_cluster.py --smoke   # CI smoke

Writes benchmarks/results/bench_cluster.csv.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List

_here = os.path.dirname(os.path.abspath(__file__))
_root = os.path.join(_here, os.pardir)
_src = os.path.join(_root, "src")
for p in (_src,):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import (CheckpointManager, FIFOScheduler, ObjectStore,
                        ProcessMeshExecutor, Resources, TrainableFactory,
                        Trial, TrialRunner, TrialStatus)

try:
    from .common import write_csv
except ImportError:
    sys.path.insert(0, _here)
    from common import write_csv

# Spawned children import the trainable from repro.testing.simworker (already
# on every worker's path via sys_path below) — no faults configured, so each
# step is `step_wall_s` of real "device work" plus the lr-separable loss.
SIM_FACTORY = TrainableFactory(
    target="repro.testing.simworker:SimWorkerTrainable", sys_path=(_src,))


def run_sweep(kind: str, n_trials: int, iters: int, step_s: float,
              n_hosts: int = 3, devices_per_trial: int = 2) -> Dict:
    total = n_trials * devices_per_trial
    common = dict(checkpoint_manager=CheckpointManager(ObjectStore()),
                  checkpoint_freq=5,
                  factory_resolver=lambda name: SIM_FACTORY)
    if kind == "cluster":
        from repro.cluster import ClusterMeshExecutor
        per_host = -(-total // n_hosts)  # ceil: roster holds the whole sweep
        executor = ClusterMeshExecutor(
            hosts=f"{n_hosts}x{per_host}", transport="socket",
            placement="fixed", devices_per_trial=devices_per_trial, **common)
    else:
        from repro.dist.submesh import SlicePool
        executor = ProcessMeshExecutor(
            total_devices=total, slice_pool=SlicePool(n_virtual=total),
            **common)
    runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), executor,
                         trainable_name="SimWorkerTrainable",
                         stopping_criteria={"training_iteration": iters})
    for i in range(n_trials):
        runner.add_trial(Trial(
            {"lr": 0.01 + i * 0.002, "sim_id": f"b{i}", "step_wall_s": step_s},
            trainable_name="SimWorkerTrainable",
            resources=Resources(cpu=1.0, devices=devices_per_trial),
            stopping_criteria={"training_iteration": iters}))
    t0 = time.time()
    trials = runner.run()
    wall = time.time() - t0
    assert all(t.status == TrialStatus.TERMINATED for t in trials), \
        [(t.status, t.error) for t in trials]
    n_results = sum(t.training_iteration for t in trials)
    ts = sorted(r.timestamp for t in trials for r in t.results)
    steady = (len(ts) - 1) / max(ts[-1] - ts[0], 1e-9) if len(ts) > 1 else 0.0
    row = {"bench": "cluster_exec", "executor": kind, "n_trials": n_trials,
           "iters": iters, "step_ms": round(step_s * 1000, 1),
           "n_hosts": n_hosts if kind == "cluster" else 1,
           "wall_s": round(wall, 3),
           "results_per_s": round(n_results / wall, 2),
           "steady_results_per_s": round(steady, 2),
           "host_evictions": (executor.n_host_evictions
                              if kind == "cluster" else 0)}
    return row


def run(n_trials: int = 6, iters: int = 20, step_ms: float = 20.0,
        n_hosts: int = 3) -> List[Dict]:
    """Harness entry (benchmarks.run): returns the result rows."""
    step_s = step_ms / 1000.0
    rows: List[Dict] = []
    for kind in ("process", "cluster"):
        row = run_sweep(kind, n_trials, iters, step_s, n_hosts=n_hosts)
        print(f"[bench_cluster] {kind:8s} wall={row['wall_s']:.3f}s "
              f"throughput={row['results_per_s']:.2f} results/s "
              f"(steady {row['steady_results_per_s']:.2f}/s)")
        rows.append(row)
    by = {r["executor"]: r for r in rows}
    for row in rows:
        row["ratio_vs_process"] = round(
            row["results_per_s"] / max(by["process"]["results_per_s"], 1e-9), 3)
        row["steady_ratio_vs_process"] = round(
            row["steady_results_per_s"]
            / max(by["process"]["steady_results_per_s"], 1e-9), 3)
    path = write_csv("bench_cluster", rows)
    print(f"[bench_cluster] cluster/process steady throughput: "
          f"{by['cluster']['steady_ratio_vs_process']:.2f}x over {n_hosts} "
          f"loopback hosts ({n_trials} trials x {iters} iters, "
          f"~{step_ms:.0f}ms steps) -> {path}")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trials", type=int, default=6)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--step-ms", type=float, default=20.0,
                    help="real per-step work, so throughput is work-bound "
                         "and the control-plane tax is the measured residue")
    ap.add_argument("--hosts", type=int, default=3)
    ap.add_argument("--min-ratio", type=float, default=0.5,
                    help="required cluster/process end-to-end throughput "
                         "ratio; on loopback the socket tier should stay "
                         "well above half the pipe tier")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: shorter sweep, same assertion")
    args = ap.parse_args()
    if args.smoke:
        args.iters = min(args.iters, 12)
        args.trials = min(args.trials, 4)

    rows = run(args.trials, args.iters, args.step_ms, n_hosts=args.hosts)
    cluster_row = [r for r in rows if r["executor"] == "cluster"][0]
    if cluster_row.get("host_evictions"):
        print(f"[bench_cluster] FAIL: {cluster_row['host_evictions']} host "
              "evictions during a healthy loopback sweep", file=sys.stderr)
        return 1
    # Gate end-to-end, not steady-state: staggered socket dial-ins widen the
    # first-to-last result window and would punish boot order, not framing.
    ratio = cluster_row["ratio_vs_process"]
    if ratio < args.min_ratio:
        print(f"[bench_cluster] FAIL: cluster throughput {ratio:.2f}x "
              f"of process tier < required {args.min_ratio:.2f}x",
              file=sys.stderr)
        return 1
    print(f"[bench_cluster] PASS: {ratio:.2f}x >= {args.min_ratio:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
