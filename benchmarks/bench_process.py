"""GIL-contention benchmark: ProcessMeshExecutor vs the in-host executors.

Each trial's step burns *host* CPU in pure Python (a GIL-bound loop — the
hyperparameter-sweep analogue of heavy data preprocessing, environment
simulation, or feature code living next to the jitted step).  On worker
threads those steps serialize on the interpreter lock no matter how many mesh
slices are free, so the concurrent executor degenerates to (at best) serial
throughput.  Worker *processes* each own an interpreter: throughput scales
with cores, and that is the gap this bench measures.

    python benchmarks/bench_process.py --trials 4 --iters 20 --step-ms 100
    python benchmarks/bench_process.py --smoke   # CI smoke

Writes benchmarks/results/bench_process.csv and exits non-zero when the
process tier is not >= --min-speedup (2x by default) faster than the
concurrent (thread) tier in result-throughput, so CI catches a regression in
the GIL-free stepping itself.  Spawn/boot cost is part of the measured wall —
the speedup is what a user actually sees for a sweep of this length.

The gate is hardware-aware: it first *measures* how far the same busy loop
scales across OS processes on this host (SMT siblings, cgroup quotas and
noisy neighbours make this far less than ``os.cpu_count()`` claims), caps the
requirement at 75% of that ceiling, and skips the gate entirely below 1.5x
measured scaling — a one-core host cannot express GIL relief for any executor.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List

_here = os.path.dirname(os.path.abspath(__file__))
_root = os.path.join(_here, os.pardir)
_src = os.path.join(_root, "src")
for p in (_src,):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import (CheckpointManager, ConcurrentMeshExecutor,
                        FIFOScheduler, ObjectStore, ProcessMeshExecutor,
                        Resources, SerialMeshExecutor, TrainableFactory, Trial,
                        TrialRunner, TrialStatus)

try:
    from .common import write_csv
    from ._busy import BusyTrainable, _burn_n
except ImportError:
    sys.path.insert(0, _here)
    from common import write_csv
    from _busy import BusyTrainable, _burn_n

# Worker processes rebuild the trainable from the featherweight _busy module —
# not from this one — so a worker's boot is a fork + one tiny import, and the
# sweep measures GIL contention rather than import graphs.
BUSY_FACTORY = TrainableFactory(target="_busy:BusyTrainable", sys_path=(_here,))


def calibrate_n_inner(step_ms: float) -> int:
    """Loop iterations for a ~``step_ms`` step on this host."""
    probe = 200_000
    t = BusyTrainable({"n_inner": probe})
    best = min(_timed(t.step) for _ in range(3))
    return max(10_000, int(step_ms / 1000.0 / (best / probe)))


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def measure_hw_scaling(n_procs: int, n_inner: int) -> float:
    """How much the busy loop actually parallelizes across ``n_procs`` OS
    processes on this host (cgroup quotas and SMT siblings make this < the
    nominal core count).  The process executor cannot beat this — it is the
    hardware ceiling the speedup gate is scaled by."""
    from repro.core.workers import _default_context

    reps = 5
    single = min(_timed(lambda: _burn_n(n_inner * reps)) for _ in range(2))
    # The workers' own context (forkserver-preloaded, spawn fallback) — a
    # plain fork here would copy a parent that may already hold JAX/XLA and
    # executor threads (harness mode runs this after the jax-heavy benches),
    # which can deadlock the child before it ever reaches burn().
    ctx = _default_context()
    procs = [ctx.Process(target=_burn_n, args=(n_inner * reps,))
             for _ in range(n_procs)]
    t0 = time.perf_counter()
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    multi = time.perf_counter() - t0
    return max(1.0, n_procs * single / multi)


def run_sweep(kind: str, n_trials: int, iters: int, n_inner: int,
              devices_per_trial: int = 2) -> Dict:
    from repro.dist.submesh import SlicePool  # lazy: keep spawn re-imports light

    total = n_trials * devices_per_trial
    pool = SlicePool(n_virtual=total)
    common = dict(checkpoint_manager=CheckpointManager(ObjectStore()),
                  total_devices=total, slice_pool=pool, checkpoint_freq=0)
    if kind == "process":
        executor = ProcessMeshExecutor(
            factory_resolver=lambda name: BUSY_FACTORY, **common)
    elif kind == "concurrent":
        executor = ConcurrentMeshExecutor(lambda n: BusyTrainable, **common)
    else:
        executor = SerialMeshExecutor(lambda n: BusyTrainable, **common)
    runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), executor,
                         stopping_criteria={"training_iteration": iters})
    for _ in range(n_trials):
        runner.add_trial(Trial({"n_inner": n_inner},
                               resources=Resources(devices=devices_per_trial),
                               stopping_criteria={"training_iteration": iters}))
    t0 = time.time()
    trials = runner.run()
    wall = time.time() - t0
    assert all(t.status == TrialStatus.TERMINATED for t in trials), \
        [(t.status, t.error) for t in trials]
    n_results = sum(t.training_iteration for t in trials)
    # Steady-state rate: first result -> last result, i.e. with worker boot
    # (interpreter fork/spawn) amortized away.  Long real sweeps approach this.
    ts = sorted(r.timestamp for t in trials for r in t.results)
    steady = (len(ts) - 1) / max(ts[-1] - ts[0], 1e-9) if len(ts) > 1 else 0.0
    return {"bench": "process_exec", "executor": kind, "n_trials": n_trials,
            "iters": iters, "n_inner": n_inner, "wall_s": round(wall, 3),
            "results_per_s": round(n_results / wall, 2),
            "steady_results_per_s": round(steady, 2)}


def run(n_trials: int = 4, iters: int = 20, step_ms: float = 100.0) -> List[Dict]:
    """Harness entry (benchmarks.run): returns the result rows."""
    n_inner = calibrate_n_inner(step_ms)
    hw_scaling = measure_hw_scaling(min(n_trials, os.cpu_count() or 1), n_inner)
    print(f"[bench_process] calibrated n_inner={n_inner} (~{step_ms:.0f}ms/step); "
          f"{os.cpu_count()} cores, measured process scaling {hw_scaling:.2f}x")
    rows: List[Dict] = []
    for kind in ("serial", "concurrent", "process"):
        row = run_sweep(kind, n_trials, iters, n_inner)
        row["hw_scaling"] = round(hw_scaling, 2)
        print(f"[bench_process] {kind:10s} wall={row['wall_s']:.3f}s "
              f"throughput={row['results_per_s']:.2f} results/s "
              f"(steady {row['steady_results_per_s']:.2f}/s)")
        rows.append(row)
    by_kind = {r["executor"]: r for r in rows}
    speedup = by_kind["process"]["results_per_s"] / by_kind["concurrent"]["results_per_s"]
    for row in rows:
        row["speedup_vs_concurrent"] = (
            round(row["results_per_s"] / by_kind["concurrent"]["results_per_s"], 2))
        row["steady_speedup_vs_concurrent"] = (
            round(row["steady_results_per_s"]
                  / max(by_kind["concurrent"]["steady_results_per_s"], 1e-9), 2))
    path = write_csv("bench_process", rows)
    print(f"[bench_process] process/concurrent result-throughput: {speedup:.2f}x "
          f"({n_trials} trials x {iters} iters, GIL-bound ~{step_ms:.0f}ms steps) "
          f"-> {path}")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trials", type=int, default=4)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--step-ms", type=float, default=100.0,
                    help="target per-step host compute (pure-Python, GIL-bound)")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="required process/concurrent throughput ratio; "
                         "automatically capped at 75%% of the *measured* "
                         "multi-process scaling of this host, so the gate "
                         "tests the executor, not the core count")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: shorter sweep, same assertion")
    args = ap.parse_args()
    if args.smoke:
        args.iters = min(args.iters, 12)

    rows = run(args.trials, args.iters, args.step_ms)
    proc_row = [r for r in rows if r["executor"] == "process"][0]
    # Smoke runs are short, so worker boot would dominate the ratio; gate on
    # the steady-state rate there (full runs amortize boot and gate end-to-end).
    key = "steady_speedup_vs_concurrent" if args.smoke else "speedup_vs_concurrent"
    speedup = proc_row[key]
    hw_scaling = rows[0]["hw_scaling"]
    if hw_scaling < 1.5:
        # A host with no measurable multi-process parallelism (single core,
        # tight cgroup quota, SMT-only siblings) cannot express GIL relief at
        # all — every tier shares one interpreter-speed core.  Report, but
        # don't fail the build on hardware the premise excludes.
        print(f"[bench_process] SKIP gate: measured process scaling "
              f"{hw_scaling:.2f}x < 1.5x — this host cannot express "
              f"GIL-contention relief (results recorded for reference)")
        return 0
    required = min(args.min_speedup, 0.75 * hw_scaling)
    if speedup < required:
        print(f"[bench_process] FAIL: speedup {speedup:.2f}x < required "
              f"{required:.2f}x (min-speedup {args.min_speedup}x capped by "
              f"0.75 * hw scaling {hw_scaling:.2f}x)", file=sys.stderr)
        return 1
    print(f"[bench_process] PASS: {speedup:.2f}x >= {required:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
