"""Shared benchmark utilities: surrogate objectives + result CSV emission."""
from __future__ import annotations

import csv
import os
import sys
import time
from typing import Any, Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def write_csv(name: str, rows: List[Dict[str, Any]]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
    return path


class SurrogateTrainable:
    """Deterministic surrogate of a training curve:

        loss(t) = quality + amplitude * decay^t + noise

    quality = (lr - lr*)^2 scaled — separates trials; decay speed varies per
    trial so trial lengths/curves are irregular (paper §3 requirement).
    """

    def __init__(self, config: Dict[str, Any]):
        from repro.core.api import Trainable  # noqa
        self.lr = float(config["lr"])
        self.seed = int(config.get("seed", 0))
        rng = np.random.default_rng(self.seed)
        self.quality = (np.log10(self.lr) + 2.0) ** 2 * 0.5  # optimum lr=1e-2
        self.decay = rng.uniform(0.85, 0.95)
        self.noise = float(config.get("noise", 0.005))
        self.rng = rng
        self.x = 1.0
        self.iteration = 0
        self.config = dict(config)

    def train(self):
        self.x *= self.decay
        self.iteration += 1
        return {"loss": self.quality + self.x + self.rng.normal(0, self.noise)}

    # Trainable-compatible surface used by the executor
    def step(self):
        return self.train()

    def save(self):
        return {"x": self.x, "lr": self.lr, "q": self.quality}

    def restore(self, s):
        self.x = s["x"]
        self.lr = s["lr"]
        self.quality = s["q"]

    def reset_config(self, cfg):
        self.lr = float(cfg["lr"])
        self.quality = (np.log10(self.lr) + 2.0) ** 2 * 0.5
        self.config = dict(cfg)
        return True

    def cleanup(self):
        pass
