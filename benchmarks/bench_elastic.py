"""Elastic reclaim benchmark: checkpoint-boundary slice resize vs static
placement on an early-stopping sweep (DESIGN.md §6).

An ASHA sweep where most trials are early-stopped frees most of the pool
while the survivors are still small — the utilization gap the elastic tier
closes.  Each trial's step costs a fixed amount of *device-time*
(``work_s`` device-seconds, simulated as ``sleep(work_s / slice.size)``), so
a survivor that absorbs freed devices finishes measurably sooner.  The bench
runs the identical sweep twice on the concurrent executor — static placement
vs ``GreedyFill`` elastic — and compares:

- **makespan**: wall time for the whole sweep;
- **device-idle time**: the integral of free pool devices over the sweep
  (sampled at every runner event), i.e. capacity bought but not used.

    python benchmarks/bench_elastic.py            # full run + gate
    python benchmarks/bench_elastic.py --smoke    # CI smoke (shorter, same gate)

Writes benchmarks/results/bench_elastic.csv and exits non-zero when the
elastic run is not at least ``--min-gain`` faster in makespan (default: 10%
— the modeled gain is ~2x, so the gate tests the mechanism, not the noise).

The gate is hardware-aware in the same spirit as bench_process: the step
cost is a ``time.sleep``, so the only way the premise breaks is a host whose
sleeps are wildly inflated (tight cgroup quota, heavily oversubscribed CI
runner).  The bench first *measures* sleep fidelity and skips the gate when
a nominal 20ms sleep takes >2x its requested duration — on such a host the
step cost is scheduler noise, not the simulated device-time.

A second, ungated section records the **lookahead credit** win: a FIFO
process-tier sweep of GIL-bound ~2ms steps at k=1 vs k=4.  With k>1 the
worker pipelines STEP commands instead of paying a pipe round-trip to the
control plane per result; the ratio is recorded in the CSV for tracking.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Any, Dict, List, Optional

_here = os.path.dirname(os.path.abspath(__file__))
_root = os.path.join(_here, os.pardir)
_src = os.path.join(_root, "src")
for p in (_src,):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import (ASHAScheduler, CheckpointManager,
                        ConcurrentMeshExecutor, FIFOScheduler, GreedyFill,
                        Logger, ObjectStore, ProcessMeshExecutor, Resources,
                        ResourceBroker, Trial, TrialRunner, TrialStatus,
                        TrainableFactory)
from repro.core.api import Trainable
from repro.dist.submesh import SlicePool

try:
    from .common import write_csv
except ImportError:
    sys.path.insert(0, _here)
    from common import write_csv

BUSY_FACTORY = TrainableFactory(target="_busy:BusyTrainable", sys_path=(_here,))


class ElasticWork(Trainable):
    """Step cost = ``work_s`` device-seconds spread over the trial's slice:
    sleep(work_s / devices).  loss = quality + 1/n separates good trials
    (small quality -> ASHA survivors) from bad ones (early-stopped)."""

    def setup(self, config):
        self.n = 0
        self.quality = float(config["quality"])
        self.work_s = float(config["work_s"])

    def step(self):
        sl = self.config.get("_slice")
        devices = sl.size if sl is not None else 1
        time.sleep(self.work_s / devices)
        self.n += 1
        return {"loss": self.quality + 1.0 / self.n, "devices": devices}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]


class _IdleSampler(Logger):
    """Integrates free pool devices over time: every runner event is a sample
    point, so the integral tracks exactly the capacity the control plane
    could have used but didn't."""

    def __init__(self, pool: SlicePool):
        self.pool = pool
        self._t = time.perf_counter()
        self._free = pool.n_free
        self.idle_device_s = 0.0

    def _sample(self) -> None:
        now = time.perf_counter()
        self.idle_device_s += self._free * (now - self._t)
        self._t, self._free = now, self.pool.n_free

    def on_result(self, trial, result):
        self._sample()

    def on_event(self, trial, event):
        self._sample()

    def on_experiment_end(self, trials):
        self._sample()


def measure_sleep_fidelity(dt: float = 0.02, reps: int = 5) -> float:
    """measured/nominal duration of a short sleep on this host.  ~1.0 on a
    sane machine; >>1 on an oversubscribed runner whose scheduler quantum
    dwarfs the simulated step cost."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        time.sleep(dt)
        best = min(best, time.perf_counter() - t0)
    return best / dt


def run_sweep(elastic: bool, n_trials: int, max_iters: int, work_s: float,
              devices_per_trial: int = 2) -> Dict[str, Any]:
    pool = SlicePool(n_virtual=n_trials * devices_per_trial)
    executor = ConcurrentMeshExecutor(
        lambda name: ElasticWork,
        CheckpointManager(ObjectStore()),
        total_devices=pool.n_total, slice_pool=pool, checkpoint_freq=0)
    scheduler = ASHAScheduler(metric="loss", mode="min", max_t=max_iters,
                              grace_period=2, reduction_factor=2)
    broker = ResourceBroker(policy=GreedyFill()) if elastic else None
    sampler = _IdleSampler(pool)
    runner = TrialRunner(scheduler, executor, logger=sampler,
                         stopping_criteria={"training_iteration": max_iters},
                         broker=broker)
    # 1/4 good trials (ASHA survivors), the rest clearly worse — early stops
    # free capacity while survivors still have most of their iterations left.
    n_good = max(1, n_trials // 4)
    for i in range(n_trials):
        quality = 0.05 * i if i < n_good else 2.0 + i
        runner.add_trial(Trial(
            {"quality": quality, "work_s": work_s},
            resources=Resources(devices=devices_per_trial),
            stopping_criteria={"training_iteration": max_iters}))
    t0 = time.perf_counter()
    trials = runner.run()
    makespan = time.perf_counter() - t0
    n_finished = sum(t.status == TrialStatus.TERMINATED for t in trials)
    assert n_finished == n_trials, [(t.status, t.error) for t in trials]
    max_devices = max(r.metrics.get("devices", 0)
                      for t in trials for r in t.results)
    return {
        "bench": "elastic_reclaim",
        "mode": "elastic" if elastic else "static",
        "n_trials": n_trials, "max_iters": max_iters, "work_s": work_s,
        "devices_per_trial": devices_per_trial,
        "makespan_s": round(makespan, 3),
        "idle_device_s": round(sampler.idle_device_s, 3),
        "n_early_stopped": scheduler.n_stopped,
        "n_resized": broker.n_resized if broker else 0,
        "max_trial_devices": max_devices,
    }


def run_lookahead(lookahead: int, n_trials: int = 2, iters: int = 120,
                  n_inner: int = 12_000) -> Dict[str, Any]:
    """FIFO process-tier sweep of short GIL-bound steps: k>1 pipelines STEPs
    in the worker pipe instead of paying a control-plane RTT per result."""
    executor = ProcessMeshExecutor(
        factory_resolver=lambda name: BUSY_FACTORY,
        checkpoint_manager=CheckpointManager(ObjectStore()),
        total_devices=n_trials, checkpoint_freq=0)
    runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), executor,
                         stopping_criteria={"training_iteration": iters},
                         broker=ResourceBroker(lookahead=lookahead))
    for _ in range(n_trials):
        runner.add_trial(Trial({"n_inner": n_inner},
                               resources=Resources(devices=1),
                               stopping_criteria={"training_iteration": iters}))
    t0 = time.perf_counter()
    trials = runner.run()
    wall = time.perf_counter() - t0
    assert all(t.status == TrialStatus.TERMINATED for t in trials), \
        [(t.status, t.error) for t in trials]
    n_results = sum(t.training_iteration for t in trials)
    ts = sorted(r.timestamp for t in trials for r in t.results)
    steady = (len(ts) - 1) / max(ts[-1] - ts[0], 1e-9) if len(ts) > 1 else 0.0
    return {"bench": "elastic_lookahead", "lookahead": lookahead,
            "n_trials": n_trials, "iters": iters, "n_inner": n_inner,
            "wall_s": round(wall, 3),
            "results_per_s": round(n_results / wall, 2),
            "steady_results_per_s": round(steady, 2)}


def run(n_trials: int = 8, max_iters: int = 10, work_s: float = 0.3,
        lookahead_iters: int = 120) -> List[Dict[str, Any]]:
    """Harness entry (benchmarks.run): returns the result rows."""
    fidelity = measure_sleep_fidelity()
    print(f"[bench_elastic] sleep fidelity {fidelity:.2f}x nominal")
    rows: List[Dict[str, Any]] = []
    for elastic in (False, True):
        row = run_sweep(elastic, n_trials, max_iters, work_s)
        row["sleep_fidelity"] = round(fidelity, 2)
        print(f"[bench_elastic] {row['mode']:8s} makespan={row['makespan_s']:.3f}s "
              f"idle={row['idle_device_s']:.2f} device-s "
              f"(stopped {row['n_early_stopped']}, resizes {row['n_resized']}, "
              f"max slice {row['max_trial_devices']})")
        rows.append(row)
    static, elastic_row = rows[0], rows[1]
    elastic_row["makespan_ratio"] = round(
        elastic_row["makespan_s"] / max(static["makespan_s"], 1e-9), 3)
    elastic_row["idle_ratio"] = round(
        elastic_row["idle_device_s"] / max(static["idle_device_s"], 1e-9), 3)

    for k in (1, 4):
        row = run_lookahead(k, iters=lookahead_iters)
        print(f"[bench_elastic] lookahead k={k}: "
              f"{row['results_per_s']:.1f} results/s "
              f"(steady {row['steady_results_per_s']:.1f}/s)")
        rows.append(row)
    k1, k4 = rows[2], rows[3]
    # End-to-end throughput, boot included: with k>1 results arrive in bursts,
    # which skews the first-to-last-timestamp "steady" window, so the honest
    # comparison is the whole sweep.
    k4["speedup_vs_k1"] = round(
        k4["results_per_s"] / max(k1["results_per_s"], 1e-9), 2)
    print(f"[bench_elastic] lookahead k=4 vs k=1 throughput: "
          f"{k4['speedup_vs_k1']:.2f}x (recorded, not gated)")

    # Two row shapes (reclaim sweep + lookahead sweep) share one CSV: pad to
    # the union of keys so DictWriter sees a uniform schema.
    fields: List[str] = []
    for row in rows:
        fields.extend(k for k in row if k not in fields)
    padded = [{k: row.get(k, "") for k in fields} for row in rows]
    path = write_csv("bench_elastic", padded)
    print(f"[bench_elastic] elastic/static makespan "
          f"{elastic_row['makespan_ratio']:.3f}, idle {elastic_row['idle_ratio']:.3f} "
          f"-> {path}")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trials", type=int, default=8)
    ap.add_argument("--max-iters", type=int, default=10)
    ap.add_argument("--work-s", type=float, default=0.3,
                    help="device-seconds of simulated work per iteration "
                         "(a trial's step sleeps work_s / slice_devices)")
    ap.add_argument("--min-gain", type=float, default=0.10,
                    help="required makespan reduction (elastic must finish in "
                         "<= (1 - min_gain) * static makespan); the modeled "
                         "gain at the default shape is ~2x")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: shorter sweep, same gate")
    args = ap.parse_args()
    if args.smoke:
        args.trials = min(args.trials, 8)
        args.max_iters = min(args.max_iters, 8)
        args.work_s = min(args.work_s, 0.25)

    rows = run(args.trials, args.max_iters, args.work_s,
               lookahead_iters=60 if args.smoke else 120)
    static, elastic = rows[0], rows[1]

    if elastic["n_resized"] == 0:
        print("[bench_elastic] FAIL: the elastic run never resized a slice — "
              "the control plane is not engaging", file=sys.stderr)
        return 1
    if elastic["sleep_fidelity"] > 2.0:
        # Sleeps (the simulated device-time) are dominated by host scheduling
        # noise: the premise — step cost scales with slice size — doesn't
        # hold here.  Report, but don't fail the build on such hardware.
        print(f"[bench_elastic] SKIP gate: sleep fidelity "
              f"{elastic['sleep_fidelity']:.2f}x > 2x — this host cannot "
              f"express the simulated device-time (results recorded)")
        return 0
    required = 1.0 - args.min_gain
    ratio = elastic["makespan_ratio"]
    if ratio > required:
        print(f"[bench_elastic] FAIL: elastic/static makespan {ratio:.3f} > "
              f"required {required:.3f} (elastic reclaim must cut makespan by "
              f">= {args.min_gain:.0%})", file=sys.stderr)
        return 1
    print(f"[bench_elastic] PASS: makespan ratio {ratio:.3f} <= {required:.3f} "
          f"(idle-device ratio {elastic['idle_ratio']:.3f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
