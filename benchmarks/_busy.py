"""GIL-bound benchmark trainable, kept in its own featherweight module.

bench_process.py's worker processes import *this* module (not bench_process
itself) so a worker boots with nothing beyond repro.core — the benchmark must
measure GIL contention, not the import graph.
"""
from __future__ import annotations

from repro.core.api import Trainable

__all__ = ["BusyTrainable", "_burn_n"]


def _burn_n(n_inner: int) -> None:
    """Module-level burn target for bench_process.measure_hw_scaling — child
    processes rebuild it by import, so it cannot be a closure."""
    BusyTrainable({"n_inner": n_inner}).step()


class BusyTrainable(Trainable):
    """One step = ``n_inner`` iterations of a pure-Python loop (holds the GIL
    the whole time; no numpy, no sleeping, nothing releases the lock)."""

    def setup(self, config):
        self.n_inner = int(config.get("n_inner", 100_000))
        self.acc = 0

    def step(self):
        acc = self.acc
        for i in range(self.n_inner):
            acc = (acc + i * i) % 1_000_000_007
        self.acc = acc
        return {"loss": 1.0 / (self.iteration + 1), "acc": float(acc % 97)}

    def save(self):
        return {"acc": self.acc}

    def restore(self, state):
        self.acc = state["acc"]
