"""Generate the EXPERIMENTS.md markdown tables from benchmark artifacts."""
from __future__ import annotations

import csv
import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _md_table(rows, cols, headers=None):
    headers = headers or cols
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    rows = []
    for fname in ("dryrun_single.json", "dryrun_multi.json"):
        path = os.path.join(RESULTS, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for r in json.load(f):
                if r.get("status") == "compiled" and r["mesh"] == mesh:
                    rows.append({
                        "arch": r["arch"], "shape": r["shape"],
                        "compute_ms": round(r["compute_s"] * 1e3, 1),
                        "memory_ms": round(r["memory_s"] * 1e3, 1),
                        "collective_ms": round(r["collective_s"] * 1e3, 1),
                        "dominant": r["dominant"],
                        "useful": round(r["useful_flops_ratio"], 3),
                        "hbm_GiB": round(r["hbm_per_device_gib"], 2),
                    })
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"])] = r
    rows = sorted(seen.values(), key=lambda r: (r["arch"], r["shape"]))
    return _md_table(rows, ["arch", "shape", "compute_ms", "memory_ms",
                            "collective_ms", "dominant", "useful", "hbm_GiB"])


def skip_table() -> str:
    rows = []
    path = os.path.join(RESULTS, "dryrun_single.json")
    if os.path.exists(path):
        with open(path) as f:
            for r in json.load(f):
                if r.get("status") == "skipped":
                    rows.append({"arch": r["arch"], "shape": r["shape"],
                                 "reason": r["reason"]})
    return _md_table(rows, ["arch", "shape", "reason"])


def csv_table(name: str) -> str:
    path = os.path.join(RESULTS, f"{name}.csv")
    if not os.path.exists(path):
        return f"(missing {name}.csv)"
    with open(path) as f:
        rows = list(csv.DictReader(f))
    if not rows:
        return "(empty)"
    return _md_table(rows, list(rows[0].keys()))


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("roofline-single", "all"):
        print("### single-pod (16x16 = 256 chips)\n")
        print(roofline_table("pod16x16"))
    if which in ("roofline-multi", "all"):
        print("\n### multi-pod (2x16x16 = 512 chips)\n")
        print(roofline_table("pods2x16x16"))
    if which in ("skips", "all"):
        print("\n### documented skips\n")
        print(skip_table())
    if which in ("loc", "all"):
        print("\n### LoC (Table 1)\n")
        print(csv_table("table1_loc"))
    if which in ("convergence", "all"):
        print("\n### convergence\n")
        print(csv_table("convergence"))
