"""Roofline table emission: reads the dry-run JSON records and produces the
per-(arch x shape x mesh) roofline CSV that EXPERIMENTS.md §Roofline cites.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from .common import RESULTS_DIR, emit, write_csv


def run() -> List[Dict]:
    rows: List[Dict] = []
    for fname in ("dryrun_single.json", "dryrun_multi.json", "dryrun_both.json"):
        path = os.path.join(RESULTS_DIR, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            records = json.load(f)
        for r in records:
            if r.get("status") != "compiled":
                continue
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "chips": r["chips"],
                "compute_ms": round(r["compute_s"] * 1e3, 2),
                "memory_ms": round(r["memory_s"] * 1e3, 2),
                "collective_ms": round(r["collective_s"] * 1e3, 2),
                "dominant": r["dominant"],
                "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
                "hbm_per_device_gib": round(r["hbm_per_device_gib"], 2),
                "step_time_s": round(r["step_time_s"], 3),
            })
    # dedupe (arch, shape, mesh)
    seen = {}
    for row in rows:
        seen[(row["arch"], row["shape"], row["mesh"])] = row
    rows = sorted(seen.values(), key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    for r in rows:
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             r["step_time_s"] * 1e6,
             f"{r['dominant']}-bound useful={r['useful_flops_ratio']}")
    write_csv("roofline", rows)
    if not rows:
        emit("roofline/none", 0.0, "run repro.launch.dryrun --out benchmarks/results first")
    return rows
