"""Benchmark harness — one bench per paper table/claim.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV lines; full tables land in
benchmarks/results/*.csv.

  table1_loc   — paper Table 1: LoC per algorithm against the narrow waist
  convergence  — scheduler quality vs budget (ASHA/HB/Median/PBT/TPE vs FIFO)
  overhead     — event-loop + checkpoint-codec throughput
  scaling      — slice-pool occupancy under irregular trials (paper §4.3.1)
  process      — GIL-contention sweep: process vs thread vs serial executors
  elastic      — elastic slice reclaim vs static placement + lookahead credits
  faults       — crash-storm recovery rate + control-plane overhead per event
  cluster      — localhost 3-host socket sweep vs the process tier
  vmap         — beyond-paper: stacked-vmap trial execution vs serial
  kernels      — pure-jnp oracle timings (TPU kernel baselines)
  roofline     — per-(arch x shape x mesh) table from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="run a single bench (loc|convergence|overhead|"
                         "scaling|async|process|elastic|faults|cluster|vmap|"
                         "kernels|roofline)")
    args = ap.parse_args()

    from . import (bench_async, bench_cluster, bench_convergence,
                   bench_elastic, bench_faults, bench_kernels, bench_loc,
                   bench_overhead, bench_process, bench_roofline,
                   bench_scaling, bench_vmap)
    benches = {
        "loc": bench_loc.run,
        "convergence": bench_convergence.run,
        "overhead": bench_overhead.run,
        "scaling": bench_scaling.run,
        "async": bench_async.run,
        "process": bench_process.run,
        "elastic": bench_elastic.run,
        "faults": lambda: bench_faults.run(2000),
        "cluster": bench_cluster.run,
        "vmap": bench_vmap.run,
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
    }
    selected = {args.only: benches[args.only]} if args.only else benches

    print("name,us_per_call,derived")
    failed = []
    for name, fn in selected.items():
        try:
            fn()
        except Exception:  # noqa: BLE001 — report all bench failures at the end
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
