"""Framework-overhead benchmark: event-loop throughput.

The narrow waist is only viable if its bookkeeping is negligible next to a
train step.  We drive the runner with a no-op trainable and measure results
processed per second vs live-trial count, plus checkpoint save/restore costs
on a realistically sized state pytree.

The observability acceptance gate (DESIGN.md §8) rides here too: the same
event loop is re-run with the default disabled ``NULL_OBS`` and with a full
``Observability`` bundle (tracing + metrics) attached.  The disabled path
must stay within noise of the historical no-obs numbers — every hot-path
guard is one pre-resolved attribute test — and the enabled overhead is
recorded (not gated) so drift is visible in the CSV.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import (CheckpointManager, FIFOScheduler, ObjectStore,
                        SerialMeshExecutor, Trainable, Trial, TrialRunner)
from repro.core.checkpoint import tree_from_bytes, tree_to_bytes
from repro.obs import Observability

from .common import emit, write_csv


class NoopTrainable(Trainable):
    def setup(self, config):
        pass

    def step(self):
        return {"loss": 0.0}

    def save(self):
        return {"ok": 1}

    def restore(self, s):
        pass


def _event_loop_us(n_trials: int, obs: Optional[Observability] = None,
                   reps: int = 3, logger=None, runner_kw=None) -> float:
    """Best-of-``reps`` microseconds per result through the serial event loop
    (best-of filters host scheduling noise out of a ~10ms-granularity wall)."""
    best = float("inf")
    for _ in range(reps):
        executor = SerialMeshExecutor(lambda n: NoopTrainable,
                                      CheckpointManager(ObjectStore()),
                                      total_devices=n_trials, checkpoint_freq=0,
                                      obs=obs)
        kw = {} if logger is None else {"logger": logger()}
        kw.update(runner_kw or {})
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), executor,
                             stopping_criteria={"training_iteration": 50},
                             obs=obs, **kw)
        for i in range(n_trials):
            runner.add_trial(Trial({}, stopping_criteria={"training_iteration": 50}))
        t0 = time.time()
        runner.run()
        wall = time.time() - t0
        best = min(best, wall / (n_trials * 50) * 1e6)
    return best


def run() -> List[Dict]:
    rows: List[Dict] = []
    for n_trials in (8, 64, 256):
        us = _event_loop_us(n_trials)
        rows.append({"bench": "event_loop", "n_trials": n_trials,
                     "results_per_s": round(1e6 / us, 1),
                     "us_per_result": round(us, 2)})
        emit(f"overhead/event_loop_n{n_trials}", us, f"{1e6/us:.0f} results/s")

    # Observability on vs off (the DESIGN.md §8 disabled-overhead gate rides
    # on the `event_loop` rows above — they ARE the disabled path, one
    # NULL_OBS attribute test per touch point).  The enabled run records the
    # full tracing+metrics cost for drift tracking.
    us_off = _event_loop_us(64)
    obs = Observability(trace=True, metrics=True)
    us_on = _event_loop_us(64, obs=obs)
    ratio = us_on / max(us_off, 1e-9)
    rows.append({"bench": "event_loop_obs_enabled", "n_trials": 64,
                 "results_per_s": round(1e6 / us_on, 1),
                 "us_per_result": round(us_on, 2)})
    emit("overhead/event_loop_obs_enabled_n64", us_on,
         f"{ratio:.2f}x disabled ({us_off:.1f}us)")

    # LiveReporter attached (DESIGN.md §9 acceptance: within 2x of obs-off).
    # The table renders to a sink and its clock throttle caps renders, so the
    # per-result cost is the dict bookkeeping, not terminal I/O.
    import io

    from repro.core.loggers import LiveReporter
    us_live = _event_loop_us(
        64, logger=lambda: LiveReporter(metric="loss", stream=io.StringIO()))
    live_ratio = us_live / max(us_off, 1e-9)
    rows.append({"bench": "event_loop_live_reporter", "n_trials": 64,
                 "results_per_s": round(1e6 / us_live, 1),
                 "us_per_result": round(us_live, 2)})
    emit("overhead/event_loop_live_reporter_n64", us_live,
         f"{live_ratio:.2f}x disabled ({us_off:.1f}us)")

    # Decision provenance on vs off (DESIGN.md §10).  The `event_loop` rows
    # above already run with journaling ON (the default); the off row drops
    # the drain+emit entirely, so the pair bounds the provenance cost.  Gated
    # like the LiveReporter row: the ratio is recorded for drift tracking
    # (acceptance: decisions-on stays within ~1.1x of decisions-off — one
    # deque drain per on_result plus one journal write per non-CONTINUE).
    us_dec_off = _event_loop_us(64, runner_kw={"decisions": False})
    us_dec_on = _event_loop_us(64, runner_kw={"decisions": True})
    dec_ratio = us_dec_on / max(us_dec_off, 1e-9)
    rows.append({"bench": "event_loop_decisions_off", "n_trials": 64,
                 "results_per_s": round(1e6 / us_dec_off, 1),
                 "us_per_result": round(us_dec_off, 2)})
    rows.append({"bench": "event_loop_decisions_on", "n_trials": 64,
                 "results_per_s": round(1e6 / us_dec_on, 1),
                 "us_per_result": round(us_dec_on, 2)})
    emit("overhead/event_loop_decisions_on_n64", us_dec_on,
         f"{dec_ratio:.2f}x decisions-off ({us_dec_off:.1f}us)")

    # checkpoint codec on a ~10M-float pytree
    tree = {"params": {f"layer{i}": np.random.default_rng(i).standard_normal(
        (256, 512)).astype(np.float32) for i in range(20)}}
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        data = tree_to_bytes(tree)
    enc = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        tree_from_bytes(data)
    dec = (time.time() - t0) / reps
    mb = len(data) / 2**20
    rows.append({"bench": "ckpt_encode", "n_trials": 0,
                 "results_per_s": round(mb / enc, 1),
                 "us_per_result": round(enc * 1e6, 1)})
    rows.append({"bench": "ckpt_decode", "n_trials": 0,
                 "results_per_s": round(mb / dec, 1),
                 "us_per_result": round(dec * 1e6, 1)})
    emit("overhead/ckpt_encode", enc * 1e6, f"{mb/enc:.0f} MiB/s ({mb:.0f} MiB)")
    emit("overhead/ckpt_decode", dec * 1e6, f"{mb/dec:.0f} MiB/s")
    write_csv("overhead", rows)
    return rows
