"""Framework-overhead benchmark: event-loop throughput.

The narrow waist is only viable if its bookkeeping is negligible next to a
train step.  We drive the runner with a no-op trainable and measure results
processed per second vs live-trial count, plus checkpoint save/restore costs
on a realistically sized state pytree.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (CheckpointManager, FIFOScheduler, ObjectStore,
                        SerialMeshExecutor, Trainable, Trial, TrialRunner)
from repro.core.checkpoint import tree_from_bytes, tree_to_bytes

from .common import emit, write_csv


class NoopTrainable(Trainable):
    def setup(self, config):
        pass

    def step(self):
        return {"loss": 0.0}

    def save(self):
        return {"ok": 1}

    def restore(self, s):
        pass


def run() -> List[Dict]:
    rows: List[Dict] = []
    for n_trials in (8, 64, 256):
        executor = SerialMeshExecutor(lambda n: NoopTrainable,
                                      CheckpointManager(ObjectStore()),
                                      total_devices=n_trials, checkpoint_freq=0)
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), executor,
                             stopping_criteria={"training_iteration": 50})
        for i in range(n_trials):
            runner.add_trial(Trial({}, stopping_criteria={"training_iteration": 50}))
        t0 = time.time()
        runner.run()
        wall = time.time() - t0
        n_results = n_trials * 50
        rows.append({"bench": "event_loop", "n_trials": n_trials,
                     "results_per_s": round(n_results / wall, 1),
                     "us_per_result": round(wall / n_results * 1e6, 2)})
        emit(f"overhead/event_loop_n{n_trials}", wall / n_results * 1e6,
             f"{n_results/wall:.0f} results/s")

    # checkpoint codec on a ~10M-float pytree
    tree = {"params": {f"layer{i}": np.random.default_rng(i).standard_normal(
        (256, 512)).astype(np.float32) for i in range(20)}}
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        data = tree_to_bytes(tree)
    enc = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        tree_from_bytes(data)
    dec = (time.time() - t0) / reps
    mb = len(data) / 2**20
    rows.append({"bench": "ckpt_encode", "n_trials": 0,
                 "results_per_s": round(mb / enc, 1),
                 "us_per_result": round(enc * 1e6, 1)})
    rows.append({"bench": "ckpt_decode", "n_trials": 0,
                 "results_per_s": round(mb / dec, 1),
                 "us_per_result": round(dec * 1e6, 1)})
    emit("overhead/ckpt_encode", enc * 1e6, f"{mb/enc:.0f} MiB/s ({mb:.0f} MiB)")
    emit("overhead/ckpt_decode", dec * 1e6, f"{mb/dec:.0f} MiB/s")
    write_csv("overhead", rows)
    return rows
