"""Async execution benchmark: ConcurrentMeshExecutor vs SerialMeshExecutor.

Each trial's step holds its slice for a fixed ``--sleep`` (simulated device
work — a jitted step's dispatch-to-completion time, during which the host
thread is idle in JAX's async runtime).  The serial executor pays
trials x iters x sleep wall-clock; the concurrent executor overlaps the
sleeps across disjoint slices, so wall-clock collapses toward iters x sleep
and result-throughput rises by ~ the live-trial count.

    python benchmarks/bench_async.py --trials 8 --iters 10 --sleep 0.05
    python benchmarks/bench_async.py --trials 4 --smoke   # CI smoke (CPU)

Writes benchmarks/results/bench_async.csv and prints the speedup; exits
non-zero if the concurrent path is not >= --min-speedup faster (1.5x by
default), so CI catches a regression in the overlap itself.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List

_here = os.path.dirname(os.path.abspath(__file__))
_src = os.path.join(_here, os.pardir, "src")
if _src not in sys.path:
    sys.path.insert(0, _src)

from repro.core import (CheckpointManager, ConcurrentMeshExecutor,
                        FIFOScheduler, ObjectStore, Resources,
                        SerialMeshExecutor, Trainable, Trial, TrialRunner,
                        TrialStatus)
from repro.dist.submesh import SlicePool

try:
    from .common import write_csv
except ImportError:
    sys.path.insert(0, _here)
    from common import write_csv


class SleepTrainable(Trainable):
    """One step = hold the slice for ``sleep_s`` (simulated device work)."""

    def setup(self, config):
        self.sleep_s = float(config.get("sleep_s", 0.05))
        self.x = 1.0

    def step(self):
        time.sleep(self.sleep_s)
        self.x *= 0.9
        return {"loss": self.x}

    def save(self):
        return {"x": self.x}

    def restore(self, state):
        self.x = state["x"]


def run_sweep(kind: str, n_trials: int, iters: int, sleep_s: float,
              devices_per_trial: int = 2) -> Dict:
    total = n_trials * devices_per_trial
    pool = SlicePool(n_virtual=total)
    common = dict(checkpoint_manager=CheckpointManager(ObjectStore()),
                  total_devices=total, slice_pool=pool, checkpoint_freq=0)
    if kind == "concurrent":
        executor = ConcurrentMeshExecutor(lambda n: SleepTrainable, **common)
    else:
        executor = SerialMeshExecutor(lambda n: SleepTrainable, **common)
    runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), executor,
                         stopping_criteria={"training_iteration": iters})
    for _ in range(n_trials):
        runner.add_trial(Trial({"sleep_s": sleep_s},
                               resources=Resources(devices=devices_per_trial),
                               stopping_criteria={"training_iteration": iters}))
    t0 = time.time()
    trials = runner.run()
    wall = time.time() - t0
    assert all(t.status == TrialStatus.TERMINATED for t in trials), \
        [t.status for t in trials]
    n_results = sum(t.training_iteration for t in trials)
    return {"bench": "async_exec", "executor": kind, "n_trials": n_trials,
            "iters": iters, "sleep_s": sleep_s, "wall_s": round(wall, 3),
            "results_per_s": round(n_results / wall, 1)}


def run(n_trials: int = 8, iters: int = 10, sleep_s: float = 0.05) -> List[Dict]:
    """Harness entry (benchmarks.run): returns the result rows."""
    rows: List[Dict] = []
    for kind in ("serial", "concurrent"):
        row = run_sweep(kind, n_trials, iters, sleep_s)
        print(f"[bench_async] {kind:10s} wall={row['wall_s']:.3f}s "
              f"throughput={row['results_per_s']:.1f} results/s")
        rows.append(row)
    speedup = rows[1]["results_per_s"] / rows[0]["results_per_s"]
    for row in rows:
        row["speedup_vs_serial"] = round(speedup, 2) if row["executor"] == "concurrent" else 1.0
    path = write_csv("bench_async", rows)
    print(f"[bench_async] concurrent/serial result-throughput: {speedup:.2f}x "
          f"({n_trials} trials x {iters} iters, {sleep_s}s/step) -> {path}")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trials", type=int, default=8)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--sleep", type=float, default=0.05,
                    help="simulated per-step device time (seconds)")
    ap.add_argument("--min-speedup", type=float, default=1.5)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small sweep, short sleeps")
    args = ap.parse_args()
    if args.smoke:
        args.iters = min(args.iters, 5)
        args.sleep = min(args.sleep, 0.02)

    rows = run(args.trials, args.iters, args.sleep)
    speedup = rows[1]["speedup_vs_serial"]
    if speedup < args.min_speedup:
        print(f"[bench_async] FAIL: speedup {speedup:.2f}x < required "
              f"{args.min_speedup}x", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
