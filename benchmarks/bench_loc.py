"""Table 1 analogue: lines of code per model-selection algorithm.

The paper's central quantitative evidence for interface generality is that
each algorithm is small when written against the narrow waist (10-215 LoC).
We count non-blank, non-comment, non-docstring lines of each scheduler module
and report them next to the paper's numbers.
"""
from __future__ import annotations

import ast
import os
import time
from typing import Dict, List

from .common import emit, write_csv

PAPER_LOC = {
    "FIFO": 10,
    "AsyncHyperBand": 78,
    "HyperBand": 215,
    "MedianStoppingRule": 68,
    "HyperOpt(TPE)": 137,
    "PBT": 169,
}

MODULES = {
    "FIFO": "src/repro/core/schedulers/fifo.py",
    "AsyncHyperBand": "src/repro/core/schedulers/asha.py",
    "HyperBand": "src/repro/core/schedulers/hyperband.py",
    "MedianStoppingRule": "src/repro/core/schedulers/median_stopping.py",
    "HyperOpt(TPE)": "src/repro/core/search/tpe.py",
    "PBT": "src/repro/core/schedulers/pbt.py",
}


def count_loc(path: str) -> int:
    """Non-blank, non-comment, non-docstring logical source lines."""
    with open(path) as f:
        src = f.read()
    tree = ast.parse(src)
    doc_lines = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            if (node.body and isinstance(node.body[0], ast.Expr)
                    and isinstance(node.body[0].value, ast.Constant)
                    and isinstance(node.body[0].value.value, str)):
                d = node.body[0]
                doc_lines.update(range(d.lineno, d.end_lineno + 1))
    n = 0
    for i, line in enumerate(src.splitlines(), start=1):
        s = line.strip()
        if not s or s.startswith("#") or i in doc_lines:
            continue
        n += 1
    return n


def run(repo_root: str = ".") -> List[Dict]:
    rows = []
    t0 = time.time()
    for name, rel in MODULES.items():
        path = os.path.join(repo_root, rel)
        loc = count_loc(path)
        rows.append({"algorithm": name, "loc_ours": loc,
                     "loc_paper": PAPER_LOC[name], "module": rel})
        emit(f"loc/{name}", (time.time() - t0) * 1e6,
             f"ours={loc} paper={PAPER_LOC[name]}")
    write_csv("table1_loc", rows)
    return rows
