"""Fault-recovery benchmark: control-plane overhead under a crash storm
(DESIGN.md §8).

Drives the deterministic scenario harness (``repro.testing``) with a
``crash_storm`` at 10^4 virtual trials on the concurrent executor: ~30% of
trials crash mid-run and restart from their last checkpoint, a sprinkle
exhaust their failure budget and end ERROR.  Every step is virtual-time
``sleep`` — zero wall-clock work — so the measured wall time *is* the control
plane: EventBus fan-in, SlicePool first-fit, ``choose_trial_to_run``,
checkpoint bookkeeping, restart orchestration.  Reported:

- **trials_recovered_per_s** — crashed-then-TERMINATED trials per wall second
  (the paper-level fault-tolerance claim: recovery is cheap);
- **us_per_event** — wall microseconds of control-plane work per bus event
  (the regression gate).

    python benchmarks/bench_faults.py             # full 10^4-trial run + gate
    python benchmarks/bench_faults.py --smoke     # CI smoke (2000 trials)

Writes benchmarks/results/bench_faults.csv and gates ``us_per_event`` against
the committed baseline (benchmarks/results/bench_faults_baseline.csv) with a
3x hardware margin — wide enough to absorb CI-runner variance, tight enough
to catch an accidentally quadratic hot path or a per-event allocation storm.
If no baseline row exists for the shape, the run bootstraps one (commit it).

A second, ungated section re-runs a smaller storm with full observability on
(tracing + metrics) and exports the Chrome trace + metrics JSONL to
benchmarks/out/ — the CI artifacts — while recording the enabled-overhead
ratio next to the disabled run.
"""
from __future__ import annotations

import argparse
import csv
import os
import sys
import time
from typing import Any, Dict, List, Optional

_here = os.path.dirname(os.path.abspath(__file__))
_root = os.path.join(_here, os.pardir)
_src = os.path.join(_root, "src")
for p in (_src,):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import FIFOScheduler, TrialStatus
from repro.obs import Observability
from repro.testing import crash_storm, run_scenario

try:
    from .common import write_csv, RESULTS_DIR
except ImportError:
    sys.path.insert(0, _here)
    from common import write_csv, RESULTS_DIR

OUT_DIR = os.path.join(_here, "out")
BASELINE = os.path.join(RESULTS_DIR, "bench_faults_baseline.csv")
GATE_MARGIN = 3.0  # x over baseline us_per_event: hardware noise, not drift


def run_storm(n_trials: int, pool_devices: int = 64, seed: int = 0,
              obs: Optional[Observability] = None,
              label: str = "disabled",
              journal_path: Optional[str] = None) -> Dict[str, Any]:
    scenario = crash_storm(n_trials=n_trials, seed=seed)
    res = run_scenario(scenario, lambda: FIFOScheduler(metric="loss", mode="min"),
                       executor="concurrent", pool_devices=pool_devices,
                       obs=obs, token=f"bench-faults-{label}-{n_trials}",
                       journal_path=journal_path)
    if obs is not None:
        obs.close(res.executor)
    trials = res.trials
    recovered = sum(1 for t in trials
                    if t.num_failures > 0 and t.status == TrialStatus.TERMINATED)
    errored = sum(1 for t in trials if t.status == TrialStatus.ERROR)
    assert errored == scenario.expected_fatal, (errored, scenario.expected_fatal)
    n_events = len(res.recorder.events) + len(res.recorder.results)
    wall = res.wall_elapsed_s
    return {
        "bench": "fault_storm", "obs": label,
        "n_trials": n_trials, "pool_devices": pool_devices,
        "recovered": recovered, "errored": errored,
        "n_events": n_events,
        "wall_s": round(wall, 3),
        "virtual_s": round(res.virtual_elapsed_s, 1),
        "trials_recovered_per_s": round(recovered / max(wall, 1e-9), 1),
        "us_per_event": round(wall / max(n_events, 1) * 1e6, 2),
    }


def read_baseline(n_trials: int) -> Optional[float]:
    """Committed baseline us_per_event for this storm shape, or None."""
    if not os.path.exists(BASELINE):
        return None
    with open(BASELINE) as f:
        for row in csv.DictReader(f):
            if (row.get("bench") == "fault_storm"
                    and int(row.get("n_trials", -1)) == n_trials
                    and row.get("obs") == "disabled"):
                return float(row["us_per_event"])
    return None


def bootstrap_baseline(row: Dict[str, Any]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    exists = os.path.exists(BASELINE)
    with open(BASELINE, "a", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(row))
        if not exists:
            w.writeheader()
        w.writerow(row)


def run(n_trials: int = 10_000, artifact_trials: int = 500,
        pool_devices: int = 64) -> List[Dict[str, Any]]:
    """Harness entry (benchmarks.run): returns the result rows (no gate)."""
    rows: List[Dict[str, Any]] = []

    row = run_storm(n_trials, pool_devices)
    print(f"[bench_faults] storm n={n_trials}: {row['recovered']} recovered, "
          f"{row['errored']} fatal in {row['wall_s']:.1f}s wall "
          f"({row['virtual_s']:.0f} virtual-s) -> "
          f"{row['trials_recovered_per_s']:.0f} recovered/s, "
          f"{row['us_per_event']:.1f} us/event over {row['n_events']} events")
    rows.append(row)

    # Observability-on artifact run: Chrome trace + metrics JSONL + JSONL
    # journal for CI (the journal feeds the repro.launch.report smoke step).
    os.makedirs(OUT_DIR, exist_ok=True)
    trace_path = os.path.join(OUT_DIR, "bench_faults_trace.json")
    metrics_path = os.path.join(OUT_DIR, "bench_faults_metrics.jsonl")
    journal_path = os.path.join(OUT_DIR, "bench_faults_events.jsonl")
    obs = Observability(trace=trace_path, metrics=metrics_path,
                        metrics_interval=60.0)
    traced = run_storm(artifact_trials, pool_devices, obs=obs, label="traced",
                       journal_path=journal_path)
    base = run_storm(artifact_trials, pool_devices, label="disabled-small")
    traced["enabled_overhead_x"] = round(
        traced["us_per_event"] / max(base["us_per_event"], 1e-9), 2)
    print(f"[bench_faults] traced n={artifact_trials}: "
          f"{traced['us_per_event']:.1f} us/event vs "
          f"{base['us_per_event']:.1f} disabled "
          f"({traced['enabled_overhead_x']:.2f}x, recorded not gated); "
          f"trace -> {trace_path}")
    rows.extend([traced, base])

    fields: List[str] = []
    for r in rows:
        fields.extend(k for k in r if k not in fields)
    padded = [{k: r.get(k, "") for k in fields} for r in rows]
    path = write_csv("bench_faults", padded)
    print(f"[bench_faults] results -> {path}")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trials", type=int, default=10_000)
    ap.add_argument("--pool-devices", type=int, default=64)
    ap.add_argument("--margin", type=float, default=GATE_MARGIN,
                    help="allowed us_per_event growth over the committed "
                         "baseline before the gate fails")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 2000-trial storm, same gate")
    args = ap.parse_args()
    if args.smoke:
        args.trials = min(args.trials, 2000)

    rows = run(args.trials, pool_devices=args.pool_devices)
    storm = rows[0]

    if storm["recovered"] == 0:
        print("[bench_faults] FAIL: the storm recovered zero trials — "
              "restart-from-checkpoint is not engaging", file=sys.stderr)
        return 1
    baseline = read_baseline(args.trials)
    if baseline is None:
        bootstrap_baseline(storm)
        print(f"[bench_faults] no committed baseline for n={args.trials}; "
              f"bootstrapped {storm['us_per_event']:.1f} us/event -> "
              f"{BASELINE} (commit it)")
        return 0
    limit = baseline * args.margin
    if storm["us_per_event"] > limit:
        print(f"[bench_faults] FAIL: {storm['us_per_event']:.1f} us/event > "
              f"{limit:.1f} (baseline {baseline:.1f} x {args.margin:.1f} "
              f"margin) — control-plane overhead regressed", file=sys.stderr)
        return 1
    print(f"[bench_faults] PASS: {storm['us_per_event']:.1f} us/event <= "
          f"{limit:.1f} (baseline {baseline:.1f}, "
          f"{storm['trials_recovered_per_s']:.0f} trials recovered/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
